//! The worker pool: one OS thread per array shard, each owning its engine
//! exclusively (no locks on the hot path).  The router validates and
//! forwards requests; each worker drains its queue in batches
//! (`max_batch`) to amortize wakeups, executes in arrival order — which
//! serializes all ops touching a shard and makes writes linearizable —
//! and replies through per-request channels.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::request::{Request, RequestId, Response, RouteError};
use crate::cim::{CimOp, CimResult, Engine, EngineError};
use crate::config::SimConfig;
use crate::metrics::RunMetrics;

enum WorkerMsg {
    Work(Request, Sender<Response>),
    /// A pre-batched request group with a single group reply (§Perf: one
    /// channel round-trip amortized over the whole group).
    Batch(Vec<Request>, Sender<Vec<Response>>),
    /// Like `Batch`, but executed through the engine's fused datapath
    /// (`Engine::execute_fused`) when it has one: dual ops over the same
    /// operand pair share one activation.  Falls back to sequential
    /// execution on engines without fusion support.
    FusedBatch(Vec<Request>, Sender<Vec<Response>>),
    /// `Batch`/`FusedBatch` with a cooperative abandon flag: the worker
    /// re-checks the flag when it DEQUEUES the group (i.e. between
    /// batches in the drain loop).  Set by then → the group is
    /// acknowledged with an empty reply and the engine is never touched
    /// — how a cancelled program's in-flight work is dropped without
    /// blocking the queue behind it.
    Guarded {
        reqs: Vec<Request>,
        tx: Sender<Vec<Response>>,
        fused: bool,
        abandon: Arc<AtomicBool>,
    },
    /// Collect a metrics snapshot.
    Stats(Sender<RunMetrics>),
    /// Override the engine's per-op-class routing (`Engine::set_routing`)
    /// — the calibration loop's actuator.  Fire-and-forget: the channel
    /// is FIFO, so the override lands before any later batch.
    SetRouting([Option<crate::planner::Executor>; 4]),
}

struct Worker {
    tx: Sender<WorkerMsg>,
    handle: Option<JoinHandle<()>>,
}

/// The coordinator: router + batcher + worker pool.
pub struct Coordinator {
    workers: Vec<Worker>,
    next_id: AtomicU64,
    cfg: SimConfig,
    /// Retained engine factory so a dead shard can be respawned with a
    /// fresh engine ([`Coordinator::respawn`] — the fault-recovery path).
    factory: Mutex<Box<dyn FnMut(usize) -> Box<dyn Engine> + Send>>,
    /// Workers respawned over this coordinator's lifetime.
    respawns: AtomicU64,
}

impl Coordinator {
    /// Build with `shards` independent array shards, each served by one
    /// worker thread running `make_engine(shard_idx)`.
    pub fn new<F>(cfg: &SimConfig, shards: usize, make_engine: F) -> Self
    where
        F: FnMut(usize) -> Box<dyn Engine> + Send + 'static,
    {
        assert!(shards > 0);
        let max_batch = cfg.max_batch;
        let mut make_engine: Box<dyn FnMut(usize) -> Box<dyn Engine> + Send> =
            Box::new(make_engine);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = channel::<WorkerMsg>();
            let engine = make_engine(shard);
            let handle = std::thread::Builder::new()
                .name(format!("adra-worker-{shard}"))
                .spawn(move || worker_loop(shard, engine, rx, max_batch))
                .expect("spawn worker");
            workers.push(Worker { tx, handle: Some(handle) });
        }
        Self {
            workers,
            next_id: AtomicU64::new(0),
            cfg: cfg.clone(),
            factory: Mutex::new(make_engine),
            respawns: AtomicU64::new(0),
        }
    }

    /// Tear down one shard's worker (dead or alive) and start a fresh one
    /// with a new engine from the retained factory.  The new engine's
    /// array starts from reset — the caller owns replaying contents into
    /// it (the serve scheduler replays from its durable `TableState`).
    pub fn respawn(&mut self, shard: usize) -> Result<(), RouteError> {
        let max_batch = self.cfg.max_batch;
        let engine = {
            let mut make = self.factory.lock().expect("engine factory");
            (*make)(shard)
        };
        let w = self.workers.get_mut(shard).ok_or(RouteError::UnknownArray(shard))?;
        let (tx, rx) = channel::<WorkerMsg>();
        drop(std::mem::replace(&mut w.tx, tx));
        if let Some(h) = w.handle.take() {
            let _ = h.join();
        }
        let handle = std::thread::Builder::new()
            .name(format!("adra-worker-{shard}"))
            .spawn(move || worker_loop(shard, engine, rx, max_batch))
            .map_err(|_| RouteError::ShuttingDown)?;
        w.handle = Some(handle);
        self.respawns.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Workers respawned over this coordinator's lifetime.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Coordinator over ADRA engines (the default deployment).
    pub fn adra(cfg: &SimConfig, shards: usize) -> Self {
        let cfg2 = cfg.clone();
        Self::new(cfg, shards, move |_| {
            Box::new(crate::cim::AdraEngine::new(&cfg2))
        })
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Submit asynchronously; the receiver yields the response.
    pub fn submit(&self, array_id: usize, op: CimOp) -> Result<PendingResponse, RouteError> {
        let worker = self
            .workers
            .get(array_id)
            .ok_or(RouteError::UnknownArray(array_id))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        worker
            .tx
            .send(WorkerMsg::Work(Request { id, array_id, op }, tx))
            .map_err(|_| RouteError::ShuttingDown)?;
        Ok(PendingResponse { id, rx })
    }

    /// Synchronous convenience call.
    pub fn call(&self, array_id: usize, op: CimOp) -> Result<CimResult, CallError> {
        let pending = self.submit(array_id, op).map_err(CallError::Route)?;
        pending.wait().map_err(CallError::Engine)
    }

    /// Submit a whole batch to one shard, then await all responses in
    /// submission order.
    ///
    /// §Perf: one shared reply channel serves the whole batch (the worker
    /// executes and replies in arrival order, so responses come back FIFO)
    /// instead of allocating a channel per request — see EXPERIMENTS.md.
    pub fn call_batch(
        &self,
        array_id: usize,
        ops: &[CimOp],
    ) -> Result<Vec<Result<CimResult, EngineError>>, RouteError> {
        let worker = self
            .workers
            .get(array_id)
            .ok_or(RouteError::UnknownArray(array_id))?;
        let max = self.cfg.max_batch.max(1);
        let mut out = Vec::with_capacity(ops.len());
        for chunk in ops.chunks(max) {
            let reqs: Vec<Request> = chunk
                .iter()
                .map(|op| Request {
                    id: self.next_id.fetch_add(1, Ordering::Relaxed),
                    array_id,
                    op: *op,
                })
                .collect();
            let ids: Vec<RequestId> = reqs.iter().map(|r| r.id).collect();
            let (tx, rx) = channel();
            worker
                .tx
                .send(WorkerMsg::Batch(reqs, tx))
                .map_err(|_| RouteError::ShuttingDown)?;
            // a dead worker surfaces as a routing error, not a panic —
            // long-lived serving threads must survive pool shutdown
            let resps = rx.recv().map_err(|_| RouteError::ShuttingDown)?;
            debug_assert_eq!(resps.len(), ids.len());
            for (resp, id) in resps.into_iter().zip(ids) {
                debug_assert_eq!(resp.id, id, "response/request id mismatch");
                out.push(resp.result);
            }
        }
        Ok(out)
    }

    /// Submit a whole batch to one shard for FUSED execution
    /// (`Engine::execute_fused`), then await all responses in submission
    /// order.
    ///
    /// Unlike `call_batch` the stream is sent as ONE group — chunking by
    /// `max_batch` would cut fusion groups at chunk boundaries — so the
    /// caller controls batch sizing.  Engines without a fused datapath
    /// fall back to sequential execution; results are identical either
    /// way (property-tested in `coordinator::fuse`).
    pub fn call_batch_fused(
        &self,
        array_id: usize,
        ops: &[CimOp],
    ) -> Result<Vec<Result<CimResult, EngineError>>, RouteError> {
        let worker = self
            .workers
            .get(array_id)
            .ok_or(RouteError::UnknownArray(array_id))?;
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        let reqs: Vec<Request> = ops
            .iter()
            .map(|op| Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                array_id,
                op: *op,
            })
            .collect();
        let (tx, rx) = channel();
        worker
            .tx
            .send(WorkerMsg::FusedBatch(reqs, tx))
            .map_err(|_| RouteError::ShuttingDown)?;
        let resps = rx.recv().map_err(|_| RouteError::ShuttingDown)?;
        debug_assert_eq!(resps.len(), ops.len());
        Ok(resps.into_iter().map(|r| r.result).collect())
    }

    /// `call_batch`/`call_batch_fused` with a cooperative abandon flag:
    /// the worker re-checks the flag when it dequeues the group — if set
    /// by then the group is abandoned (engine untouched) and `Ok(None)`
    /// comes back.  The batch is sent as ONE group like the fused path;
    /// the caller owns repairing shard state if sibling shards of the
    /// same logical round already executed (the serve scheduler replays
    /// from its durable `TableState`).
    pub fn call_batch_abandonable(
        &self,
        array_id: usize,
        ops: &[CimOp],
        fused: bool,
        abandon: &Arc<AtomicBool>,
    ) -> Result<Option<Vec<Result<CimResult, EngineError>>>, RouteError> {
        let worker = self
            .workers
            .get(array_id)
            .ok_or(RouteError::UnknownArray(array_id))?;
        if ops.is_empty() {
            return Ok(Some(Vec::new()));
        }
        if abandon.load(Ordering::Relaxed) {
            return Ok(None);
        }
        let reqs: Vec<Request> = ops
            .iter()
            .map(|op| Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                array_id,
                op: *op,
            })
            .collect();
        let (tx, rx) = channel();
        worker
            .tx
            .send(WorkerMsg::Guarded { reqs, tx, fused, abandon: abandon.clone() })
            .map_err(|_| RouteError::ShuttingDown)?;
        let resps = rx.recv().map_err(|_| RouteError::ShuttingDown)?;
        if resps.is_empty() {
            return Ok(None); // abandoned at dequeue (ops is non-empty here)
        }
        debug_assert_eq!(resps.len(), ops.len());
        Ok(Some(resps.into_iter().map(|r| r.result).collect()))
    }

    /// Push a per-op-class routing override to one shard's engine
    /// (`Engine::set_routing`).  Fire-and-forget: the per-worker channel
    /// is FIFO, so the override is applied before any batch submitted
    /// after this call returns.  Engines without a routing knob (the
    /// default `Engine` impl) silently ignore it.
    pub fn set_routing(
        &self,
        array_id: usize,
        forced: [Option<crate::planner::Executor>; 4],
    ) -> Result<(), RouteError> {
        let worker = self
            .workers
            .get(array_id)
            .ok_or(RouteError::UnknownArray(array_id))?;
        worker
            .tx
            .send(WorkerMsg::SetRouting(forced))
            .map_err(|_| RouteError::ShuttingDown)
    }

    /// Aggregate metrics across all workers.
    pub fn metrics(&self) -> RunMetrics {
        let mut total = RunMetrics::default();
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(WorkerMsg::Stats(tx)).is_ok() {
                if let Ok(m) = rx.recv() {
                    total.merge(&m);
                }
            }
        }
        total
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for w in &mut self.workers {
            // close the channel; the worker loop exits on disconnect
            let (dummy_tx, _) = channel::<WorkerMsg>();
            let tx = std::mem::replace(&mut w.tx, dummy_tx);
            drop(tx);
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Handle to an in-flight request.
pub struct PendingResponse {
    pub id: RequestId,
    rx: Receiver<Response>,
}

impl PendingResponse {
    pub fn wait(self) -> Result<CimResult, EngineError> {
        let resp = self.rx.recv().expect("worker died");
        debug_assert_eq!(resp.id, self.id);
        resp.result
    }
}

/// Errors from the synchronous call path.
#[derive(Debug)]
pub enum CallError {
    Route(RouteError),
    Engine(EngineError),
}

impl std::fmt::Display for CallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CallError::Route(e) => write!(f, "routing: {e}"),
            CallError::Engine(e) => write!(f, "engine: {e}"),
        }
    }
}

impl std::error::Error for CallError {}

/// Poll the fault injector once per request about to execute on `shard`.
/// Returns `false` when an injected worker death fires — the caller must
/// exit its loop WITHOUT replying, so pending reply channels drop and the
/// router surfaces `RouteError::ShuttingDown` (the same signature a real
/// worker crash has).  Latency spikes sleep in place.  One relaxed atomic
/// load when injection is disarmed — the zero-overhead happy path.
#[inline]
fn faults_allow(shard: usize, n: usize) -> bool {
    if !crate::faults::active() {
        return true;
    }
    for _ in 0..n {
        match crate::faults::on_worker_op(shard) {
            crate::faults::WorkerFault::None => {}
            crate::faults::WorkerFault::Delay(ns) => {
                std::thread::sleep(std::time::Duration::from_nanos(ns));
            }
            crate::faults::WorkerFault::Die => return false,
        }
    }
    true
}

/// Execute one request group on the worker's engine — through
/// `Engine::execute_fused` when `fused` is set and the engine supports
/// it, sequentially otherwise — recording metrics per result.  `None`
/// means an injected death fired: the group dies un-replied.
fn run_group(
    shard: usize,
    engine: &mut dyn Engine,
    reqs: Vec<Request>,
    fused: bool,
    metrics: &mut RunMetrics,
) -> Option<Vec<Response>> {
    if !faults_allow(shard, reqs.len()) {
        return None;
    }
    let results: Vec<Result<CimResult, EngineError>> = if fused {
        let ops: Vec<CimOp> = reqs.iter().map(|r| r.op).collect();
        match engine.execute_fused(&ops) {
            Some(rs) => rs,
            None => ops.iter().map(|op| engine.execute(op)).collect(),
        }
    } else {
        reqs.iter().map(|r| engine.execute(&r.op)).collect()
    };
    debug_assert_eq!(results.len(), reqs.len());
    Some(
        reqs.into_iter()
            .zip(results)
            .map(|(req, result)| {
                match &result {
                    Ok(r) => metrics.record(&r.cost),
                    Err(_) => metrics.record_error(),
                }
                Response { id: req.id, result }
            })
            .collect(),
    )
}

/// Metrics snapshot with the engine's array counters attached (per-tier
/// activation split included) — collected only on `Stats` requests, so
/// the request hot path never pays for it.
fn snapshot(engine: &dyn Engine, metrics: &RunMetrics) -> RunMetrics {
    let mut m = metrics.clone();
    if let Some(s) = engine.array_stats() {
        m.array = s;
    }
    m
}

/// Execute gathered single requests in arrival order.  Returns `false`
/// when an injected death fires mid-flush — undrained requests are
/// dropped un-replied (the `Drain` guard clears the whole range), and
/// the caller must exit the worker loop.
fn flush_singles(
    shard: usize,
    engine: &mut dyn Engine,
    metrics: &mut RunMetrics,
    batch: &mut Vec<(Request, Sender<Response>)>,
) -> bool {
    for (req, tx) in batch.drain(..) {
        if !faults_allow(shard, 1) {
            return false;
        }
        let result = engine.execute(&req.op);
        match &result {
            Ok(r) => metrics.record(&r.cost),
            Err(_) => metrics.record_error(),
        }
        let _ = tx.send(Response { id: req.id, result });
    }
    true
}

fn worker_loop(shard: usize, mut engine: Box<dyn Engine>, rx: Receiver<WorkerMsg>, max_batch: usize) {
    let mut metrics = RunMetrics::default();
    let mut batch: Vec<(Request, Sender<Response>)> = Vec::with_capacity(max_batch);
    loop {
        // block for the first message
        let mut group_reply: Option<(Vec<Request>, Sender<Vec<Response>>, bool)> = None;
        match rx.recv() {
            Err(_) => return, // disconnected: shutdown
            Ok(WorkerMsg::Stats(tx)) => {
                let _ = tx.send(snapshot(&*engine, &metrics));
                continue;
            }
            Ok(WorkerMsg::Work(req, tx)) => batch.push((req, tx)),
            Ok(WorkerMsg::Batch(reqs, tx)) => group_reply = Some((reqs, tx, false)),
            Ok(WorkerMsg::FusedBatch(reqs, tx)) => group_reply = Some((reqs, tx, true)),
            Ok(WorkerMsg::Guarded { reqs, tx, fused, abandon }) => {
                if abandon.load(Ordering::Relaxed) {
                    let _ = tx.send(Vec::new()); // abandoned: ack, engine untouched
                    continue;
                }
                group_reply = Some((reqs, tx, fused));
            }
            Ok(WorkerMsg::SetRouting(forced)) => {
                engine.set_routing(forced);
                continue;
            }
        }
        // grouped fast path: execute the whole group, one reply message
        if let Some((reqs, tx, fused)) = group_reply {
            match run_group(shard, &mut *engine, reqs, fused, &mut metrics) {
                Some(resps) => {
                    let _ = tx.send(resps);
                }
                None => return, // injected death: die un-replied
            }
            continue;
        }
        // opportunistically drain up to max_batch single requests
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(WorkerMsg::Work(req, tx)) => batch.push((req, tx)),
                Ok(WorkerMsg::Stats(tx)) => {
                    let _ = tx.send(snapshot(&*engine, &metrics));
                }
                Ok(WorkerMsg::SetRouting(forced)) => {
                    // singles gathered so far arrived before the override;
                    // flush them first so routing changes in arrival order
                    if !flush_singles(shard, &mut *engine, &mut metrics, &mut batch) {
                        return;
                    }
                    engine.set_routing(forced);
                }
                Ok(msg @ WorkerMsg::Batch(..))
                | Ok(msg @ WorkerMsg::FusedBatch(..))
                | Ok(msg @ WorkerMsg::Guarded { .. }) => {
                    // execute inline to preserve arrival order: first
                    // flush the singles gathered so far, then the group
                    if !flush_singles(shard, &mut *engine, &mut metrics, &mut batch) {
                        return;
                    }
                    let (reqs, tx, fused) = match msg {
                        WorkerMsg::Batch(reqs, tx) => (reqs, tx, false),
                        WorkerMsg::FusedBatch(reqs, tx) => (reqs, tx, true),
                        WorkerMsg::Guarded { reqs, tx, fused, abandon } => {
                            if abandon.load(Ordering::Relaxed) {
                                let _ = tx.send(Vec::new());
                                continue;
                            }
                            (reqs, tx, fused)
                        }
                        _ => unreachable!(),
                    };
                    match run_group(shard, &mut *engine, reqs, fused, &mut metrics) {
                        Some(resps) => {
                            let _ = tx.send(resps);
                        }
                        None => return,
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break,
            }
        }
        // execute in arrival order (linearizes the shard)
        if !flush_singles(shard, &mut *engine, &mut metrics, &mut batch) {
            return;
        }
    }
}

/// Helpers shared by stress tests and benches.
pub fn mirror_engine(cfg: &SimConfig) -> Arc<Mutex<crate::cim::AdraEngine>> {
    Arc::new(Mutex::new(crate::cim::AdraEngine::new(cfg)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{AdraEngine, CimValue, WordAddr};
    use crate::config::SensingScheme;
    use crate::workload::{OpMix, WorkloadGen};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.max_batch = 8;
        c
    }

    #[test]
    fn basic_write_then_sub() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 2);
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 40 })
            .unwrap();
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 15 })
            .unwrap();
        let r = coord.call(0, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(25));
    }

    #[test]
    fn shards_are_independent() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 2);
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 7 })
            .unwrap();
        // shard 1 never saw the write
        let r = coord.call(1, CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r.value, CimValue::Word(0));
        let r0 = coord.call(0, CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r0.value, CimValue::Word(7));
    }

    #[test]
    fn unknown_shard_rejected() {
        let coord = Coordinator::adra(&cfg(), 1);
        assert!(matches!(
            coord.submit(5, CimOp::Read(WordAddr { row: 0, word: 0 })),
            Err(RouteError::UnknownArray(5))
        ));
    }

    #[test]
    fn batched_equals_unbatched() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        let mut mirror = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 77);
        let ops = gen.batch(300);
        let batched = coord.call_batch(0, &ops).unwrap();
        for (op, got) in ops.iter().zip(batched) {
            let want = mirror.execute(op);
            match (got, want) {
                (Ok(g), Ok(w)) => assert_eq!(g.value, w.value, "op {op:?}"),
                (Err(ge), Err(we)) => assert_eq!(
                    std::mem::discriminant(&ge),
                    std::mem::discriminant(&we)
                ),
                (g, w) => panic!("divergence on {op:?}: {g:?} vs {w:?}"),
            }
        }
    }

    #[test]
    fn guarded_batch_runs_when_flag_clear_and_abandons_when_set() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        let ops: Vec<CimOp> = (0..4)
            .map(|w| CimOp::Write { addr: WordAddr { row: 0, word: w }, value: 3 + w as u64 })
            .collect();

        // clear flag: behaves exactly like call_batch
        let clear = Arc::new(AtomicBool::new(false));
        let res = coord
            .call_batch_abandonable(0, &ops, false, &clear)
            .expect("route ok")
            .expect("flag clear: executed");
        assert_eq!(res.len(), ops.len());
        let before = coord.metrics().ops;

        // set flag: the group is acknowledged without touching the engine
        let set = Arc::new(AtomicBool::new(true));
        let res = coord.call_batch_abandonable(0, &ops, true, &set).expect("route ok");
        assert!(res.is_none(), "abandoned group returns None");
        assert_eq!(coord.metrics().ops, before, "engine never saw the abandoned ops");

        // empty op list is not an abandonment
        let res = coord.call_batch_abandonable(0, &[], false, &set).expect("route ok");
        assert!(matches!(res, Some(v) if v.is_empty()));

        // the shard keeps serving afterwards
        let got = coord.call(0, CimOp::Read(WordAddr { row: 0, word: 0 })).expect("read");
        assert_eq!(got.value, CimValue::Word(3));
    }

    #[test]
    fn responses_match_request_ids_under_concurrency() {
        let cfg = cfg();
        let coord = std::sync::Arc::new(Coordinator::adra(&cfg, 4));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = coord.clone();
            let cfg2 = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let mut gen = WorkloadGen::new(&cfg2, OpMix::balanced(), 1000 + t);
                let ops = gen.batch(200);
                let shard = (t % 4) as usize;
                let res = c.call_batch(shard, &ops).unwrap();
                assert_eq!(res.len(), ops.len(), "1:1 request/response");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.ops + m.errors, 4 * 200);
    }

    #[test]
    fn metrics_accumulate() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        for i in 0..10 {
            coord
                .call(0, CimOp::Write { addr: WordAddr { row: i, word: 0 }, value: i as u64 })
                .unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.ops, 10);
        assert!(m.energy.total() > 0.0);
    }

    #[test]
    fn metrics_surface_per_tier_activation_split() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 2);
        for shard in 0..2 {
            coord
                .call(shard, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 9 })
                .unwrap();
            coord
                .call(shard, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 5 })
                .unwrap();
            coord.call(shard, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.array.dual_activations, 2, "one dual op per shard");
        assert_eq!(m.array.digital_activations, 2, "default tier is digital");
        assert_eq!(m.array.xval_mismatches, 0);
        assert!(m.array.writes >= 4);
    }

    #[test]
    fn fused_batch_matches_unbatched() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        let mut mirror = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 991);
        let ops = gen.batch(300);
        let fused = coord.call_batch_fused(0, &ops).unwrap();
        assert_eq!(fused.len(), ops.len());
        for (op, got) in ops.iter().zip(fused) {
            let want = mirror.execute(op);
            match (got, want) {
                (Ok(g), Ok(w)) => assert_eq!(g.value, w.value, "op {op:?}"),
                (Err(ge), Err(we)) => assert_eq!(
                    std::mem::discriminant(&ge),
                    std::mem::discriminant(&we)
                ),
                (g, w) => panic!("divergence on {op:?}: {g:?} vs {w:?}"),
            }
        }
    }

    #[test]
    fn fused_batch_shares_activations() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        let mut ops = vec![
            CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 77 },
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 33 },
        ];
        for _ in 0..6 {
            ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: 0 });
            ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: 0 });
        }
        let fused: f64 = coord
            .call_batch_fused(0, &ops)
            .unwrap()
            .iter()
            .map(|r| r.as_ref().unwrap().cost.energy.total())
            .sum();
        let coord2 = Coordinator::adra(&cfg, 1);
        let plain: f64 = coord2
            .call_batch(0, &ops)
            .unwrap()
            .iter()
            .map(|r| r.as_ref().unwrap().cost.energy.total())
            .sum();
        assert!(
            fused < 0.5 * plain,
            "12 dual ops on one pair must fuse: {fused:e} vs {plain:e}"
        );
    }

    /// A worker that dies mid-batch must surface as `ShuttingDown`, not a
    /// client-side panic (long-lived serving threads depend on this).
    #[test]
    fn dead_worker_surfaces_as_route_error() {
        struct PanicEngine;
        impl Engine for PanicEngine {
            fn execute(&mut self, _op: &CimOp) -> Result<CimResult, EngineError> {
                panic!("engine down");
            }
            fn name(&self) -> &'static str {
                "panic"
            }
        }
        let cfg = cfg();
        let coord = Coordinator::new(&cfg, 1, |_| Box::new(PanicEngine) as Box<dyn Engine>);
        let ops = vec![CimOp::Read(WordAddr { row: 0, word: 0 })];
        assert_eq!(
            coord.call_batch(0, &ops).unwrap_err(),
            RouteError::ShuttingDown
        );
        // and the fused path reports the same
        assert_eq!(
            coord.call_batch_fused(0, &ops).unwrap_err(),
            RouteError::ShuttingDown
        );
    }

    /// Routing overrides reach the worker's engine and apply before any
    /// batch submitted after `set_routing` returns (FIFO channel).
    #[test]
    fn routing_override_reaches_worker_engine() {
        use crate::planner::{Executor, OpClass, PlannedEngine};
        use std::sync::atomic::AtomicUsize;

        static PINS_SEEN: AtomicUsize = AtomicUsize::new(0);
        struct SpyEngine;
        impl Engine for SpyEngine {
            fn execute(&mut self, _op: &CimOp) -> Result<CimResult, EngineError> {
                Err(EngineError::Unsupported("spy".into()))
            }
            fn set_routing(&mut self, forced: [Option<Executor>; 4]) {
                PINS_SEEN.store(
                    forced.iter().filter(|p| p.is_some()).count(),
                    Ordering::SeqCst,
                );
            }
            fn name(&self) -> &'static str {
                "spy"
            }
        }

        let cfg = cfg();
        let coord = Coordinator::new(&cfg, 1, |_| Box::new(SpyEngine) as Box<dyn Engine>);
        let mut forced = [None; 4];
        forced[OpClass::Dual as usize] = Some(Executor::Baseline);
        coord.set_routing(0, forced).unwrap();
        // a subsequent round-trip guarantees the override was processed
        let _ = coord.call(0, CimOp::Read(WordAddr { row: 0, word: 0 }));
        assert_eq!(PINS_SEEN.load(Ordering::SeqCst), 1);
        assert!(matches!(
            coord.set_routing(9, forced),
            Err(RouteError::UnknownArray(9))
        ));

        // and a PlannedEngine actually honors the pin end-to-end
        let cfg3 = cfg.clone();
        let coord2 = Coordinator::new(&cfg, 1, move |_| {
            Box::new(PlannedEngine::new(&cfg3, crate::planner::Objective::Energy))
                as Box<dyn Engine>
        });
        coord2.set_routing(0, forced).unwrap();
        coord2
            .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 9 })
            .unwrap();
        coord2
            .call(0, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 4 })
            .unwrap();
        let r = coord2.call(0, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(5), "pinned routing preserves semantics");
    }

    /// `respawn` replaces a (live or dead) worker with a fresh engine
    /// from the retained factory; serving resumes on a reset array.
    /// (Injected-death recovery end-to-end is in `tests/durability.rs` —
    /// arming the process-global injector would perturb parallel tests.)
    #[test]
    fn respawn_replaces_worker_with_fresh_engine() {
        let cfg = cfg();
        let mut coord = Coordinator::adra(&cfg, 2);
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 7 })
            .unwrap();
        coord.respawn(0).unwrap();
        assert_eq!(coord.respawns(), 1);
        // fresh engine: the pre-respawn write is gone (replay is the
        // serve layer's job), and the shard serves again
        let r = coord.call(0, CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r.value, CimValue::Word(0));
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 40 })
            .unwrap();
        coord
            .call(0, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 15 })
            .unwrap();
        let r = coord.call(0, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(25));
        // untouched shards are unaffected
        assert!(matches!(coord.respawn(9), Err(RouteError::UnknownArray(9))));
    }

    /// With injection compiled in but DISARMED, batches execute exactly
    /// as before — the acceptance criterion's zero-overhead happy path.
    #[test]
    fn disarmed_faults_do_not_perturb_execution() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        let mut mirror = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 55);
        let ops = gen.batch(100);
        for (op, got) in ops.iter().zip(coord.call_batch(0, &ops).unwrap()) {
            let want = mirror.execute(op);
            match (got, want) {
                (Ok(g), Ok(w)) => assert_eq!(g.value, w.value),
                (Err(_), Err(_)) => {}
                (g, w) => panic!("divergence on {op:?}: {g:?} vs {w:?}"),
            }
        }
    }

    #[test]
    fn write_read_ordering_is_linearized() {
        let cfg = cfg();
        let coord = Coordinator::adra(&cfg, 1);
        // interleave writes and reads to the same word in one batch;
        // arrival order must be preserved
        let ops = vec![
            CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 1 },
            CimOp::Read(WordAddr { row: 0, word: 0 }),
            CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 2 },
            CimOp::Read(WordAddr { row: 0, word: 0 }),
        ];
        let res = coord.call_batch(0, &ops).unwrap();
        assert_eq!(res[1].as_ref().unwrap().value, CimValue::Word(1));
        assert_eq!(res[3].as_ref().unwrap().value, CimValue::Word(2));
    }
}
