//! Request/response protocol between clients and the coordinator.

use crate::cim::{CimOp, CimResult, EngineError};

/// Monotonic request identifier (unique per coordinator).
pub type RequestId = u64;

/// A routed CiM request: which array shard, which operation.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: RequestId,
    pub array_id: usize,
    pub op: CimOp,
}

/// The response paired to a request id.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub result: Result<CimResult, EngineError>,
}

/// Routing / submission failures (before an engine ever sees the op).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouteError {
    UnknownArray(usize),
    ShuttingDown,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownArray(id) => write!(f, "unknown array shard {id}"),
            RouteError::ShuttingDown => write!(f, "coordinator is shutting down"),
        }
    }
}

impl std::error::Error for RouteError {}
