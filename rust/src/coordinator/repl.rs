//! Line-protocol front-end for the coordinator ("serve" mode).
//!
//! A tiny text protocol over any `BufRead`/`Write` pair (the CLI wires it
//! to stdin/stdout), so the engine can be driven interactively or by
//! scripts without linking against the crate:
//!
//! ```text
//! write <shard> <row> <word> <value>
//! read  <shard> <row> <word>
//! read2 <shard> <rowA> <rowB> <word>
//! bool  <shard> <fn> <rowA> <rowB> <word>     fn: and|or|nand|nor|xor|xnor|andnot|ornot
//! add   <shard> <rowA> <rowB> <word>
//! sub   <shard> <rowA> <rowB> <word>
//! cmp   <shard> <rowA> <rowB> <word>
//! stats
//! metrics [json]
//! health
//! calibration [reset]
//! trace [clear | cap <n>]
//! faults [<spec> | off]
//! snapshot <dir>
//! restore <dir>
//! breaker
//! degrade
//! cancel <tenant>
//! quit
//! ```
//!
//! Responses are single lines: `ok <value...>` / `err <message>` —
//! except `metrics` (Prometheus text or JSON scrape of the global
//! observe registry, after publishing this coordinator's counters under
//! `source="repl"`), `trace` (the flight recorder's JSONL tail), and
//! `health` (samples the global series store, evaluates the health
//! rules, prints the per-rule report), and `calibration` (the shared
//! calibration store's factor/routing table — what any serve queue in
//! this process mirrors after each absorb; `calibration reset` clears
//! it back to the analytic tables), which emit their multi-line payload
//! and then a terminating `ok`.  `trace clear` empties the ring;
//! `trace cap <n>` resizes it (postmortem depth).
//!
//! `faults` manages the process-global deterministic fault injector
//! (`crate::faults`): `faults` alone prints the active spec (or `off`),
//! `faults off` disarms it, and `faults <spec>` installs a parsed
//! [`FaultSpec`](crate::faults::FaultSpec) (e.g. `faults death=40
//! death-max=2 spike=16 spike-ns=500000`).  `snapshot <dir>` and
//! `restore <dir>` drive the attached serving layer's durable store
//! (see [`serve_with_queue`]); without an attached queue they report
//! `err`.  `breaker` (per-shard circuit-breaker states), `degrade`
//! (brownout-ladder level), and `cancel <tenant>` (sweep a tenant's
//! queued programs) drive the overload-survival layer and likewise
//! need an attached queue.

use std::io::{BufRead, Write};

use super::pool::Coordinator;
use crate::cim::{BoolFn, CimOp, CimValue, WordAddr};
use crate::logic::CompareResult;

/// Parse one protocol line into a (shard, op) pair, `Ok(None)` for quit.
pub fn parse_line(line: &str) -> Result<Option<(usize, CimOp)>, String> {
    let mut it = line.split_whitespace();
    let cmd = match it.next() {
        None => return Err("empty command".into()),
        Some(c) => c,
    };
    let mut num = |name: &str| -> Result<usize, String> {
        it.next()
            .ok_or_else(|| format!("{cmd}: missing <{name}>"))?
            .parse::<usize>()
            .map_err(|e| format!("{cmd}: bad <{name}>: {e}"))
    };
    match cmd {
        "quit" | "exit" => Ok(None),
        "write" => {
            let shard = num("shard")?;
            let row = num("row")?;
            let word = num("word")?;
            let value = num("value")? as u64;
            Ok(Some((shard, CimOp::Write { addr: WordAddr { row, word }, value })))
        }
        "read" => {
            let shard = num("shard")?;
            let row = num("row")?;
            let word = num("word")?;
            Ok(Some((shard, CimOp::Read(WordAddr { row, word }))))
        }
        "bool" => {
            let shard = num("shard")?;
            let f = match it.next().ok_or("bool: missing <fn>")? {
                "and" => BoolFn::And,
                "or" => BoolFn::Or,
                "nand" => BoolFn::Nand,
                "nor" => BoolFn::Nor,
                "xor" => BoolFn::Xor,
                "xnor" => BoolFn::Xnor,
                "andnot" => BoolFn::AndNot,
                "ornot" => BoolFn::OrNot,
                other => return Err(format!("bool: unknown fn {other:?}")),
            };
            let mut num2 = |name: &str| -> Result<usize, String> {
                it.next()
                    .ok_or_else(|| format!("bool: missing <{name}>"))?
                    .parse::<usize>()
                    .map_err(|e| format!("bool: bad <{name}>: {e}"))
            };
            let row_a = num2("rowA")?;
            let row_b = num2("rowB")?;
            let word = num2("word")?;
            Ok(Some((shard, CimOp::Bool { f, row_a, row_b, word })))
        }
        "read2" | "add" | "sub" | "cmp" => {
            let shard = num("shard")?;
            let row_a = num("rowA")?;
            let row_b = num("rowB")?;
            let word = num("word")?;
            let op = match cmd {
                "read2" => CimOp::Read2 { row_a, row_b, word },
                "add" => CimOp::Add { row_a, row_b, word },
                "sub" => CimOp::Sub { row_a, row_b, word },
                _ => CimOp::Compare { row_a, row_b, word },
            };
            Ok(Some((shard, op)))
        }
        other => Err(format!("unknown command {other:?}")),
    }
}

/// Render a CimValue as a protocol response payload.
pub fn render_value(v: &CimValue) -> String {
    match v {
        CimValue::None => "ok".into(),
        CimValue::Word(w) => format!("ok {w}"),
        CimValue::Pair(a, b) => format!("ok {a} {b}"),
        CimValue::Sum(s) => format!("ok {s}"),
        CimValue::Diff(d) => format!("ok {d}"),
        CimValue::Ordering(o) => format!(
            "ok {}",
            match o {
                CompareResult::Less => "lt",
                CompareResult::Equal => "eq",
                CompareResult::Greater => "gt",
            }
        ),
    }
}

/// Serve the protocol until EOF or `quit`.  Returns ops served.
pub fn serve<R: BufRead, W: Write>(
    coord: &Coordinator,
    input: R,
    output: W,
) -> std::io::Result<u64> {
    serve_with_stats(coord, input, output, || None)
}

/// Like [`serve`], with an extra stats source: when a serving layer is
/// attached, `stats` additionally prints its cache/fusion counters
/// (e.g. `|| Some(queue.metrics().report("serve-layer"))`).
pub fn serve_with_stats<R: BufRead, W: Write, F: Fn() -> Option<String>>(
    coord: &Coordinator,
    input: R,
    output: W,
    extra_stats: F,
) -> std::io::Result<u64> {
    serve_session(coord, input, output, extra_stats, None)
}

/// Like [`serve_with_stats`], with a serving layer attached: `snapshot
/// <dir>` and `restore <dir>` round-trip the queue's durable state
/// through [`snapshot_to`](crate::serve::ServeQueue::snapshot_to) /
/// [`restore_from`](crate::serve::ServeQueue::restore_from).
pub fn serve_with_queue<R: BufRead, W: Write, F: Fn() -> Option<String>>(
    coord: &Coordinator,
    input: R,
    output: W,
    extra_stats: F,
    queue: &crate::serve::ServeQueue,
) -> std::io::Result<u64> {
    serve_session(coord, input, output, extra_stats, Some(queue))
}

fn serve_session<R: BufRead, W: Write, F: Fn() -> Option<String>>(
    coord: &Coordinator,
    input: R,
    mut output: W,
    extra_stats: F,
    queue: Option<&crate::serve::ServeQueue>,
) -> std::io::Result<u64> {
    let mut served = 0;
    for line in input.lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        if trimmed == "stats" {
            writeln!(output, "ok {}", coord.metrics().report("serve"))?;
            if let Some(extra) = extra_stats() {
                writeln!(output, "ok {extra}")?;
            }
            continue;
        }
        if trimmed == "metrics" || trimmed == "metrics json" {
            let reg = crate::observe::global();
            coord.metrics().publish(reg, &[("source", "repl")]);
            let body = if trimmed.ends_with("json") {
                crate::observe::expose_json(reg)
            } else {
                crate::observe::expose_text(reg)
            };
            output.write_all(body.as_bytes())?;
            if !body.ends_with('\n') {
                writeln!(output)?;
            }
            writeln!(output, "ok")?;
            continue;
        }
        if trimmed == "health" {
            // same publish-then-derive path the serve scheduler runs:
            // the report reflects this coordinator's latest counters
            let reg = crate::observe::global();
            coord.metrics().publish(reg, &[("source", "repl")]);
            let store = crate::observe::series();
            store.sample(reg);
            let mut engine = crate::observe::health().lock().expect("health lock");
            engine.evaluate(store, reg, crate::observe::recorder());
            output.write_all(engine.report().as_bytes())?;
            writeln!(output, "ok")?;
            continue;
        }
        if trimmed == "calibration" {
            let store = crate::planner::calibrate::shared().lock().expect("calibration lock");
            writeln!(output, "{}", store.report())?;
            writeln!(output, "ok")?;
            continue;
        }
        if trimmed == "calibration reset" {
            crate::planner::calibrate::shared()
                .lock()
                .expect("calibration lock")
                .clear();
            writeln!(output, "ok")?;
            continue;
        }
        if trimmed == "trace" {
            output.write_all(crate::observe::recorder().to_jsonl().as_bytes())?;
            writeln!(output, "ok")?;
            continue;
        }
        if trimmed == "trace clear" {
            crate::observe::recorder().clear();
            writeln!(output, "ok")?;
            continue;
        }
        if let Some(arg) = trimmed.strip_prefix("trace cap") {
            match arg.trim().parse::<usize>() {
                Ok(n) if n > 0 => {
                    crate::observe::recorder().set_capacity(n);
                    writeln!(output, "ok {}", crate::observe::recorder().capacity())?;
                }
                _ => writeln!(output, "err trace cap: expected a positive integer")?,
            }
            continue;
        }
        if trimmed == "faults" {
            match crate::faults::spec() {
                Some(s) => writeln!(output, "ok {}", s.render())?,
                None => writeln!(output, "ok off")?,
            }
            continue;
        }
        if trimmed == "faults off" {
            crate::faults::clear();
            writeln!(output, "ok off")?;
            continue;
        }
        if let Some(arg) = trimmed.strip_prefix("faults ") {
            match crate::faults::FaultSpec::parse(arg) {
                Ok(spec) => {
                    let rendered = spec.render();
                    crate::faults::install(spec);
                    writeln!(output, "ok {rendered}")?;
                }
                Err(e) => writeln!(output, "err faults: {e}")?,
            }
            continue;
        }
        if trimmed == "snapshot" || trimmed.starts_with("snapshot ") {
            let dir = trimmed.strip_prefix("snapshot").unwrap_or("").trim();
            if dir.is_empty() {
                writeln!(output, "err snapshot: expected <dir>")?;
            } else {
                match queue {
                    None => writeln!(output, "err snapshot: no serving layer attached")?,
                    Some(q) => match q.snapshot_to(dir) {
                        Ok(()) => writeln!(output, "ok {dir}")?,
                        Err(e) => writeln!(output, "err snapshot: {e}")?,
                    },
                }
            }
            continue;
        }
        if trimmed == "restore" || trimmed.starts_with("restore ") {
            let dir = trimmed.strip_prefix("restore").unwrap_or("").trim();
            if dir.is_empty() {
                writeln!(output, "err restore: expected <dir>")?;
            } else {
                match queue {
                    None => writeln!(output, "err restore: no serving layer attached")?,
                    Some(q) => match q.restore_from(dir) {
                        Ok(()) => writeln!(output, "ok {dir}")?,
                        Err(e) => writeln!(output, "err restore: {e}")?,
                    },
                }
            }
            continue;
        }
        if trimmed == "breaker" {
            match queue {
                None => writeln!(output, "err breaker: no serving layer attached")?,
                Some(q) => match q.lifecycle() {
                    Ok(r) => {
                        let states: Vec<String> = r
                            .breaker
                            .iter()
                            .enumerate()
                            .map(|(s, st)| format!("{s}:{st}"))
                            .collect();
                        writeln!(
                            output,
                            "ok {} ({} opens / {} closes)",
                            states.join(" "),
                            r.breaker_opens,
                            r.breaker_closes
                        )?;
                    }
                    Err(e) => writeln!(output, "err breaker: {e}")?,
                },
            }
            continue;
        }
        if trimmed == "degrade" {
            match queue {
                None => writeln!(output, "err degrade: no serving layer attached")?,
                Some(q) => match q.lifecycle() {
                    Ok(r) => writeln!(
                        output,
                        "ok {} (level {}, brownout {})",
                        r.degrade,
                        r.degrade_level,
                        if r.brownout_armed { "armed" } else { "off" }
                    )?,
                    Err(e) => writeln!(output, "err degrade: {e}")?,
                },
            }
            continue;
        }
        if trimmed == "cancel" || trimmed.starts_with("cancel ") {
            let arg = trimmed.strip_prefix("cancel").unwrap_or("").trim();
            match arg.parse::<usize>() {
                Err(_) => writeln!(output, "err cancel: expected <tenant>")?,
                Ok(tenant) => match queue {
                    None => writeln!(output, "err cancel: no serving layer attached")?,
                    Some(q) => match q.cancel_tenant(tenant) {
                        Ok(n) => writeln!(output, "ok {n}")?,
                        Err(e) => writeln!(output, "err cancel: {e}")?,
                    },
                },
            }
            continue;
        }
        match parse_line(trimmed) {
            Ok(None) => break,
            Ok(Some((shard, op))) => {
                match coord.call(shard, op) {
                    Ok(r) => writeln!(output, "{}", render_value(&r.value))?,
                    Err(e) => writeln!(output, "err {e}")?,
                }
                served += 1;
            }
            Err(e) => writeln!(output, "err {e}")?,
        }
    }
    Ok(served)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};

    fn coord() -> Coordinator {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        Coordinator::adra(&cfg, 2)
    }

    #[test]
    fn parse_all_commands() {
        assert!(matches!(
            parse_line("write 0 1 2 200").unwrap(),
            Some((0, CimOp::Write { .. }))
        ));
        assert!(matches!(
            parse_line("read 1 3 0").unwrap(),
            Some((1, CimOp::Read(_)))
        ));
        assert!(matches!(
            parse_line("bool 0 xor 1 2 0").unwrap(),
            Some((0, CimOp::Bool { f: BoolFn::Xor, .. }))
        ));
        assert!(matches!(
            parse_line("sub 0 1 2 3").unwrap(),
            Some((0, CimOp::Sub { .. }))
        ));
        assert!(parse_line("quit").unwrap().is_none());
    }

    #[test]
    fn parse_errors_are_informative() {
        assert!(parse_line("write 0 1").unwrap_err().contains("missing"));
        assert!(parse_line("bool 0 frob 1 2 0").unwrap_err().contains("unknown fn"));
        assert!(parse_line("dance").unwrap_err().contains("unknown command"));
        assert!(parse_line("read 0 x 0").unwrap_err().contains("bad"));
    }

    #[test]
    fn end_to_end_session() {
        let c = coord();
        let script = "\
# comment lines are skipped
write 0 0 0 77
write 0 1 0 33
sub 0 0 1 0
cmp 0 0 1 0
read2 0 0 1 0
bool 0 andnot 0 1 0
read 5 0 0
stats
quit
";
        let mut out = Vec::new();
        let served = serve(&c, script.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "ok");
        assert_eq!(lines[1], "ok");
        assert_eq!(lines[2], "ok 44");
        assert_eq!(lines[3], "ok gt");
        assert_eq!(lines[4], "ok 77 33");
        assert_eq!(lines[5], "ok 76"); // 77 & !33 = 0b01001100
        assert!(lines[6].starts_with("err"), "bad shard must error: {}", lines[6]);
        assert!(lines[7].starts_with("ok serve:"));
        assert_eq!(served, 7);
    }

    #[test]
    fn stats_includes_tail_latency_and_attached_serve_counters() {
        use crate::config::SimConfig;
        use crate::planner::Objective;
        use crate::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
        use crate::workload::analytics_scenario;

        let mut cfg = SimConfig::square(64, crate::config::SensingScheme::Current);
        cfg.word_bits = 8;
        let queue = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: 2,
            objective: Objective::Edp,
            n_records: 24,
            max_round: 8,
            cache_capacity: 64,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
            default_deadline: None,
            max_tenant_backlog: 0,
            retry_budget_ms: 50,
            breaker_threshold: 3,
            breaker_probe_after: 2,
            brownout: false,
        });
        let s = analytics_scenario(&cfg, 24, 1);
        queue.submit(0, s.program).unwrap().wait().unwrap();

        let c = coord();
        c.call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 5 }).unwrap();
        let mut out = Vec::new();
        serve_with_stats(&c, "stats\nquit\n".as_bytes(), &mut out, || {
            Some(queue.metrics().report("serve-layer"))
        })
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("ok serve:"), "{}", lines[0]);
        assert!(lines[0].contains("p50/p95/p99"), "tail latency: {}", lines[0]);
        assert!(lines[1].starts_with("ok serve-layer:"), "{}", lines[1]);
        assert!(lines[1].contains("hit rate"), "{}", lines[1]);
        // control-plane counters ride the same stats line
        assert!(lines[1].contains("quota hits"), "{}", lines[1]);
        assert!(lines[1].contains("controller max_round"), "{}", lines[1]);
        assert!(lines[1].contains("evictions"), "{}", lines[1]);
    }

    #[test]
    fn metrics_command_scrapes_the_global_registry() {
        let c = coord();
        c.call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 9 }).unwrap();
        c.call(0, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let mut out = Vec::new();
        serve(&c, "metrics\nmetrics json\ntrace\nquit\n".as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("# TYPE adra_run_ops counter"), "{text}");
        assert!(text.contains("adra_run_ops{source=\"repl\"} 2"), "{text}");
        assert!(
            text.contains("adra_run_op_latency_ns_bucket{le=\"+Inf\",source=\"repl\"} 2")
                || text.contains("adra_run_op_latency_ns_bucket{source=\"repl\",le=\"+Inf\"} 2"),
            "{text}"
        );
        assert!(text.contains("\"name\":\"adra.run.ops\""), "json scrape: {text}");
        // each multi-line payload terminates with a bare ok
        assert!(text.lines().filter(|l| *l == "ok").count() >= 3, "{text}");
    }

    #[test]
    fn health_command_prints_rule_report() {
        let c = coord();
        c.call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 1 }).unwrap();
        let mut out = Vec::new();
        serve(&c, "health\nquit\n".as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("overall:"), "{text}");
        assert!(text.contains("round_wall_slo_burn"), "standard rules listed: {text}");
        assert!(text.contains("tenant_quota_starvation"), "{text}");
        assert!(text.lines().any(|l| l == "ok"), "{text}");
    }

    #[test]
    fn calibration_command_reports_shared_store() {
        let c = coord();
        // Reset first: other tests in this process may have populated the
        // shared store, and the empty-store banner is the only output that
        // is deterministic under parallel test execution.
        let mut out = Vec::new();
        serve(&c, "calibration reset\ncalibration\nquit\n".as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(
            text.contains("calibration: empty (analytic tables in effect)"),
            "{text}"
        );
        // reset's ok + calibration's ok
        assert!(text.lines().filter(|l| *l == "ok").count() >= 2, "{text}");
    }

    #[test]
    fn trace_cap_knob_parses_and_rejects() {
        let c = coord();
        let before = crate::observe::recorder().capacity();
        let script = format!("trace cap 8192\ntrace cap zero\ntrace clear\ntrace cap {before}\nquit\n");
        let mut out = Vec::new();
        serve(&c, script.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "ok 8192");
        assert!(lines[1].starts_with("err trace cap"), "{}", lines[1]);
        assert_eq!(lines[2], "ok", "trace clear acknowledges");
        assert_eq!(lines[3], format!("ok {before}"), "capacity restored");
        assert_eq!(crate::observe::recorder().capacity(), before);
    }

    /// Only the non-mutating `faults` paths run here: install-based
    /// round-trips live in `tests/durability.rs` where the global
    /// injector is serialized behind `faults::test_lock()`.
    #[test]
    fn faults_and_store_commands_reject_bad_input() {
        let c = coord();
        let script = "faults death=zero\nsnapshot\nrestore\nsnapshot /tmp/x\nrestore /tmp/x\nbreaker\ndegrade\ncancel 0\ncancel x\nquit\n";
        let mut out = Vec::new();
        serve(&c, script.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("err faults: death"), "{}", lines[0]);
        assert!(lines[1].starts_with("err snapshot: expected <dir>"), "{}", lines[1]);
        assert!(lines[2].starts_with("err restore: expected <dir>"), "{}", lines[2]);
        // no serving layer attached on the plain serve() entry point
        assert!(lines[3].starts_with("err snapshot: no serving layer"), "{}", lines[3]);
        assert!(lines[4].starts_with("err restore: no serving layer"), "{}", lines[4]);
        assert!(lines[5].starts_with("err breaker: no serving layer"), "{}", lines[5]);
        assert!(lines[6].starts_with("err degrade: no serving layer"), "{}", lines[6]);
        assert!(lines[7].starts_with("err cancel: no serving layer"), "{}", lines[7]);
        assert!(lines[8].starts_with("err cancel: expected <tenant>"), "{}", lines[8]);
    }

    #[test]
    fn snapshot_and_restore_drive_the_attached_queue() {
        use crate::planner::Objective;
        use crate::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
        use crate::workload::analytics_scenario;

        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        let queue = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: 2,
            objective: Objective::Edp,
            n_records: 24,
            max_round: 8,
            cache_capacity: 64,
            admission: AdmissionPolicy::Fifo,
            batch: BatchPolicy::Static,
            sample_every: 0,
            calibrate_every: 0,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 0,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
            default_deadline: None,
            max_tenant_backlog: 0,
            retry_budget_ms: 50,
            breaker_threshold: 3,
            breaker_probe_after: 2,
            brownout: false,
        });
        let s = analytics_scenario(&cfg, 24, 7);
        queue.submit(0, s.program).unwrap().wait().unwrap();

        let dir = std::env::temp_dir().join(format!("adra_repl_snap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().into_owned();
        let c = coord();
        let script =
            format!("snapshot {dir_s}\nrestore {dir_s}\nbreaker\ndegrade\ncancel 9\nquit\n");
        let mut out = Vec::new();
        serve_with_queue(&c, script.as_bytes(), &mut out, || None, &queue).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], format!("ok {dir_s}"), "{text}");
        assert_eq!(lines[1], format!("ok {dir_s}"), "{text}");
        // lifecycle commands against a healthy idle queue
        assert_eq!(lines[2], "ok 0:closed 1:closed (0 opens / 0 closes)", "{text}");
        assert_eq!(lines[3], "ok normal (level 0, brownout off)", "{text}");
        assert_eq!(lines[4], "ok 0", "tenant 9 has nothing queued: {text}");
        assert_eq!(queue.metrics().recoveries, 1, "restore counts as a recovery");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_values() {
        assert_eq!(render_value(&CimValue::Diff(-5)), "ok -5");
        assert_eq!(render_value(&CimValue::Pair(1, 2)), "ok 1 2");
        assert_eq!(
            render_value(&CimValue::Ordering(CompareResult::Equal)),
            "ok eq"
        );
    }
}
