//! L3 coordinator: request router, per-shard batcher, and the worker pool
//! that owns the array engines.  Built on OS threads + channels (the
//! offline environment has no tokio); one engine per thread means the hot
//! path takes no locks.

pub mod fuse;
pub mod pool;
pub mod repl;
pub mod request;

pub use pool::{CallError, Coordinator, PendingResponse};
pub use request::{Request, RequestId, Response, RouteError};
