//! Domain workload traces — the scenarios the paper's introduction
//! motivates (database analytics, signal/image processing): realistic op
//! sequences with known ground-truth answers for end-to-end validation.

use crate::cim::{CimOp, WordAddr};
use crate::config::SimConfig;
use crate::util::rng::Rng;

/// A database-filter workload: N records stored in one row region, a
/// query threshold in another; the filter `SELECT * WHERE value < k`
/// runs as in-memory comparisons.
#[derive(Clone, Debug)]
pub struct DatabaseTrace {
    /// (row, word) of each stored record.
    pub records: Vec<WordAddr>,
    /// value of each record (ground truth).
    pub values: Vec<u64>,
    /// row holding the broadcast threshold.
    pub threshold_row: usize,
    pub threshold: u64,
    /// setup ops (writes), then the query ops (compares).
    pub setup: Vec<CimOp>,
    pub query: Vec<CimOp>,
    /// ground-truth record indices matching value < threshold (signed).
    pub expected_matches: Vec<usize>,
}

/// Build a database-filter trace: records in rows `0..rows_used`, the
/// threshold replicated across one extra row so every compare is a
/// same-column dual-row activation.
pub fn database_filter_trace(cfg: &SimConfig, n_records: usize, seed: u64) -> DatabaseTrace {
    let words = cfg.words_per_row();
    let rows_needed = n_records.div_ceil(words);
    assert!(
        rows_needed + 1 <= cfg.rows,
        "trace needs {} rows, array has {}",
        rows_needed + 1,
        cfg.rows
    );
    let mask = if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 };
    // keep values in the positive signed range so two's-complement
    // comparison semantics match plain unsigned intuition in the example
    let pos_max = mask >> 1;
    let mut rng = Rng::new(seed);
    let threshold = pos_max / 2;
    let threshold_row = rows_needed;

    let mut records = Vec::with_capacity(n_records);
    let mut values = Vec::with_capacity(n_records);
    let mut setup = Vec::new();
    let mut query = Vec::new();
    let mut expected_matches = Vec::new();

    for i in 0..n_records {
        let addr = WordAddr { row: i / words, word: i % words };
        let value = rng.below(pos_max + 1);
        records.push(addr);
        values.push(value);
        setup.push(CimOp::Write { addr, value });
        if value < threshold {
            expected_matches.push(i);
        }
    }
    // threshold broadcast into every word of the threshold row
    for w in 0..words {
        setup.push(CimOp::Write {
            addr: WordAddr { row: threshold_row, word: w },
            value: threshold,
        });
    }
    for addr in &records {
        query.push(CimOp::Compare { row_a: addr.row, row_b: threshold_row, word: addr.word });
    }

    DatabaseTrace { records, values, threshold_row, threshold, setup, query, expected_matches }
}

/// An image-diff workload: two frames stored row-interleaved; the diff
/// (frame1 - frame2, per pixel-word) runs as in-memory subtractions.
/// Returns (setup ops, diff ops, expected signed diffs).
pub fn image_diff_trace(
    cfg: &SimConfig,
    n_pixels: usize,
    seed: u64,
) -> (Vec<CimOp>, Vec<CimOp>, Vec<i128>) {
    let words = cfg.words_per_row();
    let rows_per_frame = n_pixels.div_ceil(words);
    assert!(2 * rows_per_frame <= cfg.rows, "frames don't fit");
    let mask = if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 };
    let bits = cfg.word_bits;
    let signed = |v: u64| -> i128 {
        let raw = (v & mask) as i128;
        if bits < 64 && (v >> (bits - 1)) & 1 == 1 {
            raw - (1i128 << bits)
        } else {
            raw
        }
    };
    let mut rng = Rng::new(seed);
    let mut setup = Vec::new();
    let mut diffs = Vec::new();
    let mut expected = Vec::new();
    for i in 0..n_pixels {
        let (row1, word) = (i / words, i % words);
        let row2 = rows_per_frame + row1;
        // second frame = first frame + small noise (temporally correlated)
        let p1 = rng.below(mask + 1);
        let noise = rng.below(16) as i64 - 8;
        let p2 = (p1 as i64 + noise).clamp(0, mask as i64) as u64;
        setup.push(CimOp::Write { addr: WordAddr { row: row1, word }, value: p1 });
        setup.push(CimOp::Write { addr: WordAddr { row: row2, word }, value: p2 });
        diffs.push(CimOp::Sub { row_a: row1, row_b: row2, word });
        expected.push(signed(p1) - signed(p2));
    }
    (setup, diffs, expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{AdraEngine, CimValue, Engine};
    use crate::config::{SensingScheme, SimConfig};
    use crate::logic::CompareResult;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    #[test]
    fn database_trace_ground_truth_via_engine() {
        let cfg = cfg();
        let trace = database_filter_trace(&cfg, 32, 99);
        let mut e = AdraEngine::new(&cfg);
        for op in &trace.setup {
            e.execute(op).unwrap();
        }
        let mut matches = Vec::new();
        for (i, op) in trace.query.iter().enumerate() {
            let r = e.execute(op).unwrap();
            if r.value == CimValue::Ordering(CompareResult::Less) {
                matches.push(i);
            }
        }
        assert_eq!(matches, trace.expected_matches);
        assert!(!trace.expected_matches.is_empty(), "degenerate trace");
        assert!(trace.expected_matches.len() < 32, "degenerate trace");
    }

    #[test]
    fn image_diff_ground_truth_via_engine() {
        let cfg = cfg();
        let (setup, diffs, expected) = image_diff_trace(&cfg, 48, 123);
        let mut e = AdraEngine::new(&cfg);
        for op in &setup {
            e.execute(op).unwrap();
        }
        for (op, want) in diffs.iter().zip(&expected) {
            let got = e.execute(op).unwrap();
            assert_eq!(got.value, CimValue::Diff(*want));
        }
    }

    #[test]
    fn trace_capacity_check_panics_when_too_big() {
        let cfg = cfg();
        let r = std::panic::catch_unwind(|| database_filter_trace(&cfg, 100_000, 1));
        assert!(r.is_err());
    }
}
