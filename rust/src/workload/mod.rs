//! Workload generators: the op streams the examples, benches, and the
//! coordinator's end-to-end driver feed through the engines, plus
//! planner-level IR programs with ground truth (`programs`).

pub mod generators;
pub mod programs;
pub mod traces;

pub use generators::{OpMix, WorkloadGen};
pub use programs::{
    analytics_scenario, diff_scenario, heavy_tenant_scenario, AnalyticsScenario, DiffScenario,
    HeavyTenantScenario,
};
pub use traces::{database_filter_trace, image_diff_trace, DatabaseTrace};
