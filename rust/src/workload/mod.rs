//! Workload generators: the op streams the examples, benches, and the
//! coordinator's end-to-end driver feed through the engines.

pub mod generators;
pub mod traces;

pub use generators::{OpMix, WorkloadGen};
pub use traces::{database_filter_trace, image_diff_trace, DatabaseTrace};
