//! Randomized operation-stream generator with a configurable op mix and
//! uniform or zipf-skewed row addressing.

use crate::cim::{BoolFn, CimOp, WordAddr};
use crate::config::SimConfig;
use crate::util::rng::Rng;

/// Relative weights of the operation classes.
#[derive(Clone, Copy, Debug)]
pub struct OpMix {
    pub read: f64,
    pub read2: f64,
    pub boolean: f64,
    pub add: f64,
    pub sub: f64,
    pub compare: f64,
    pub write: f64,
}

impl OpMix {
    /// The paper's motivating mix: subtraction/comparison-heavy.
    pub fn subtraction_heavy() -> Self {
        Self { read: 0.1, read2: 0.1, boolean: 0.1, add: 0.1, sub: 0.4, compare: 0.15, write: 0.05 }
    }

    /// Balanced mix across everything.
    pub fn balanced() -> Self {
        Self { read: 1.0, read2: 1.0, boolean: 1.0, add: 1.0, sub: 1.0, compare: 1.0, write: 1.0 }
    }

    /// Pure in-memory subtraction (the headline benchmark op).
    pub fn sub_only() -> Self {
        Self { read: 0.0, read2: 0.0, boolean: 0.0, add: 0.0, sub: 1.0, compare: 0.0, write: 0.0 }
    }

    fn total(&self) -> f64 {
        self.read + self.read2 + self.boolean + self.add + self.sub + self.compare + self.write
    }
}

/// Deterministic op-stream generator.
pub struct WorkloadGen {
    rng: Rng,
    rows: usize,
    words: usize,
    word_mask: u64,
    mix: OpMix,
    /// zipf skew on rows; 0 = uniform.
    skew: f64,
}

impl WorkloadGen {
    pub fn new(cfg: &SimConfig, mix: OpMix, seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            rows: cfg.rows,
            words: cfg.words_per_row(),
            word_mask: if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 },
            mix,
            skew: 0.0,
        }
    }

    pub fn with_skew(mut self, skew: f64) -> Self {
        self.skew = skew;
        self
    }

    fn row(&mut self) -> usize {
        if self.skew > 0.0 {
            // zipf over a window of 64 hot rows + uniform tail
            if self.rng.next_f64() < 0.8 {
                self.rng.zipf(64.min(self.rows as u64), self.skew) as usize
            } else {
                self.rng.below(self.rows as u64) as usize
            }
        } else {
            self.rng.below(self.rows as u64) as usize
        }
    }

    fn row_pair(&mut self) -> (usize, usize) {
        let a = self.row();
        let mut b = self.row();
        while b == a {
            b = (b + 1) % self.rows;
        }
        (a, b)
    }

    /// Generate the next operation.
    #[allow(unused_assignments)] // the final macro arm's decrement is dead by design
    pub fn next_op(&mut self) -> CimOp {
        let mut pick = self.rng.next_f64() * self.mix.total();
        let word = self.rng.below(self.words as u64) as usize;
        macro_rules! take {
            ($w:expr, $body:expr) => {
                if pick < $w {
                    return $body;
                }
                pick -= $w;
            };
        }
        take!(self.mix.read, {
            CimOp::Read(WordAddr { row: self.row(), word })
        });
        take!(self.mix.read2, {
            let (row_a, row_b) = self.row_pair();
            CimOp::Read2 { row_a, row_b, word }
        });
        take!(self.mix.boolean, {
            let (row_a, row_b) = self.row_pair();
            let f = BoolFn::ALL[self.rng.below(BoolFn::ALL.len() as u64) as usize];
            CimOp::Bool { f, row_a, row_b, word }
        });
        take!(self.mix.add, {
            let (row_a, row_b) = self.row_pair();
            CimOp::Add { row_a, row_b, word }
        });
        take!(self.mix.sub, {
            let (row_a, row_b) = self.row_pair();
            CimOp::Sub { row_a, row_b, word }
        });
        take!(self.mix.compare, {
            let (row_a, row_b) = self.row_pair();
            CimOp::Compare { row_a, row_b, word }
        });
        CimOp::Write {
            addr: WordAddr { row: self.row(), word },
            value: self.rng.next_u64() & self.word_mask,
        }
    }

    /// Generate a batch of ops.
    pub fn batch(&mut self, n: usize) -> Vec<CimOp> {
        (0..n).map(|_| self.next_op()).collect()
    }

    /// Random word value within the configured width.
    pub fn word_value(&mut self) -> u64 {
        self.rng.next_u64() & self.word_mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(256, SensingScheme::Current);
        c.word_bits = 16;
        c
    }

    #[test]
    fn ops_respect_address_bounds() {
        let cfg = cfg();
        let mut g = WorkloadGen::new(&cfg, OpMix::balanced(), 42);
        for _ in 0..2000 {
            let op = g.next_op();
            let (ra, rb) = op.rows();
            assert!(ra < cfg.rows);
            if let Some(rb) = rb {
                assert!(rb < cfg.rows);
                assert_ne!(ra, rb, "dual op must use distinct rows");
            }
        }
    }

    #[test]
    fn sub_only_mix_generates_only_sub() {
        let cfg = cfg();
        let mut g = WorkloadGen::new(&cfg, OpMix::sub_only(), 1);
        for _ in 0..100 {
            assert!(matches!(g.next_op(), CimOp::Sub { .. }));
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = cfg();
        let mut g1 = WorkloadGen::new(&cfg, OpMix::balanced(), 7);
        let mut g2 = WorkloadGen::new(&cfg, OpMix::balanced(), 7);
        assert_eq!(g1.batch(100), g2.batch(100));
    }

    #[test]
    fn deterministic_for_seed_with_skew_and_divergent_across_seeds() {
        let cfg = cfg();
        let mut g1 = WorkloadGen::new(&cfg, OpMix::subtraction_heavy(), 7).with_skew(1.2);
        let mut g2 = WorkloadGen::new(&cfg, OpMix::subtraction_heavy(), 7).with_skew(1.2);
        assert_eq!(g1.batch(500), g2.batch(500));
        let mut g3 = WorkloadGen::new(&cfg, OpMix::subtraction_heavy(), 8).with_skew(1.2);
        assert_ne!(g1.batch(500), g3.batch(500), "different seeds must diverge");
    }

    /// Empirical op-class frequencies must track the OpMix weights.  With
    /// n = 20000 draws the worst per-class sigma is sqrt(p(1-p)/n) <
    /// 0.0036, so a +-0.02 absolute tolerance is > 5 sigma — stable under
    /// any seed while still catching a broken weighting.
    #[test]
    fn empirical_frequencies_match_mix_weights() {
        let cfg = cfg();
        let n = 20_000usize;
        for (label, mix) in [
            ("subtraction_heavy", OpMix::subtraction_heavy()),
            ("balanced", OpMix::balanced()),
        ] {
            let mut g = WorkloadGen::new(&cfg, mix, 12345);
            let mut counts = [0usize; 7];
            for _ in 0..n {
                let k = match g.next_op() {
                    CimOp::Read(_) => 0,
                    CimOp::Read2 { .. } => 1,
                    CimOp::Bool { .. } => 2,
                    CimOp::Add { .. } => 3,
                    CimOp::Sub { .. } => 4,
                    CimOp::Compare { .. } => 5,
                    CimOp::Write { .. } => 6,
                };
                counts[k] += 1;
            }
            let total = mix.read
                + mix.read2
                + mix.boolean
                + mix.add
                + mix.sub
                + mix.compare
                + mix.write;
            let want = [
                mix.read, mix.read2, mix.boolean, mix.add, mix.sub, mix.compare, mix.write,
            ];
            for (k, &w) in want.iter().enumerate() {
                let expect = w / total;
                let got = counts[k] as f64 / n as f64;
                assert!(
                    (got - expect).abs() < 0.02,
                    "{label} class {k}: got {got:.4}, want {expect:.4}"
                );
            }
        }
    }

    #[test]
    fn mix_produces_all_classes() {
        let cfg = cfg();
        let mut g = WorkloadGen::new(&cfg, OpMix::balanced(), 3);
        let ops = g.batch(2000);
        let has = |f: &dyn Fn(&CimOp) -> bool| ops.iter().any(|o| f(o));
        assert!(has(&|o| matches!(o, CimOp::Read(_))));
        assert!(has(&|o| matches!(o, CimOp::Read2 { .. })));
        assert!(has(&|o| matches!(o, CimOp::Bool { .. })));
        assert!(has(&|o| matches!(o, CimOp::Add { .. })));
        assert!(has(&|o| matches!(o, CimOp::Sub { .. })));
        assert!(has(&|o| matches!(o, CimOp::Compare { .. })));
        assert!(has(&|o| matches!(o, CimOp::Write { .. })));
    }

    #[test]
    fn skewed_rows_are_skewed() {
        let cfg = cfg();
        let mut g = WorkloadGen::new(&cfg, OpMix::sub_only(), 9).with_skew(1.2);
        let mut low = 0;
        for _ in 0..2000 {
            let (ra, _) = g.next_op().rows();
            if ra < 8 {
                low += 1;
            }
        }
        // 8/256 rows would get ~60 hits if uniform; skew should 5x that
        assert!(low > 300, "low-row hits {low}");
    }
}
