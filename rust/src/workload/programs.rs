//! Planner-level workloads: IR programs with host-side ground truth, the
//! program-granularity counterpart of `traces` (which builds raw `CimOp`
//! streams).  Examples, benches, and integration tests feed these through
//! `planner::{lower, place}` and validate the outputs.

use crate::config::SimConfig;
use crate::planner::ir::{AggKind, Predicate, Program};
use crate::util::rng::Rng;

/// A database-analytics program (`SELECT * WHERE value < k`, a full
/// three-way compare pass, and a min aggregate) plus its ground truth.
#[derive(Clone, Debug)]
pub struct AnalyticsScenario {
    pub program: Program,
    /// Record values, in record order (positive signed range so
    /// two's-complement compare matches unsigned intuition).
    pub values: Vec<u64>,
    pub threshold: u64,
    /// IR step indices of the interesting ops in `program.ops`.
    pub filter_step: usize,
    pub compare_step: usize,
    pub aggregate_step: usize,
    /// Ground truth for the filter step.
    pub expected_matches: Vec<usize>,
    /// Ground truth for the aggregate step (lowest index wins ties).
    pub expected_min_index: usize,
}

/// Build the filter+compare+aggregate analytics program over `n_records`
/// random records.
pub fn analytics_scenario(cfg: &SimConfig, n_records: usize, seed: u64) -> AnalyticsScenario {
    assert!(n_records > 0, "scenario needs records");
    let mask = if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 };
    let pos_max = mask >> 1;
    let threshold = pos_max / 2;
    let mut rng = Rng::new(seed);
    let values: Vec<u64> = (0..n_records).map(|_| rng.below(pos_max + 1)).collect();

    let mut program = Program::new(n_records);
    let t = program.scratch();
    let all = program.all();
    program.load(0, values.clone());
    program.broadcast(t, threshold);
    program.filter(all, t, Predicate::Lt);
    program.compare(all, t);
    program.aggregate(all, AggKind::Min);

    let expected_matches: Vec<usize> = values
        .iter()
        .enumerate()
        .filter(|(_, &v)| v < threshold)
        .map(|(i, _)| i)
        .collect();
    let expected_min_index = (0..n_records).min_by_key(|&i| (values[i], i)).unwrap();

    AnalyticsScenario {
        program,
        values,
        threshold,
        filter_step: 2,
        compare_step: 3,
        aggregate_step: 4,
        expected_matches,
        expected_min_index,
    }
}

/// A dashboard-style derived-metric program over the SAME table as
/// [`analytics_scenario`]: per-record signed differences against a
/// broadcast reference, plus a SUM aggregate.
///
/// Built from the same seed it loads the same values and broadcasts the
/// same constant as the analytics program, so when both are served
/// together the serving layer dedupes the loads/broadcast and the sub
/// ops fuse onto the compare ops' activations (same operand pairs).
#[derive(Clone, Debug)]
pub struct DiffScenario {
    pub program: Program,
    pub values: Vec<u64>,
    pub reference: u64,
    pub sub_step: usize,
    pub aggregate_step: usize,
    /// Ground truth for the sub step, in record order.
    pub expected_diffs: Vec<i128>,
    /// Ground truth for the SUM aggregate (over record values).
    pub expected_sum: u128,
}

/// Build the sub+sum scenario over the same `n_records` random records
/// as `analytics_scenario(cfg, n_records, seed)`.
pub fn diff_scenario(cfg: &SimConfig, n_records: usize, seed: u64) -> DiffScenario {
    assert!(n_records > 0, "scenario needs records");
    let mask = if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 };
    let pos_max = mask >> 1;
    let reference = pos_max / 2; // == the analytics threshold
    let mut rng = Rng::new(seed);
    let values: Vec<u64> = (0..n_records).map(|_| rng.below(pos_max + 1)).collect();

    let mut program = Program::new(n_records);
    let r = program.scratch();
    let all = program.all();
    program.load(0, values.clone());
    program.broadcast(r, reference);
    program.sub(all, r);
    program.aggregate(all, AggKind::Sum);

    let expected_diffs: Vec<i128> =
        values.iter().map(|&v| v as i128 - reference as i128).collect();
    let expected_sum: u128 = values.iter().map(|&v| v as u128).sum();

    DiffScenario {
        program,
        values,
        reference,
        sub_step: 2,
        aggregate_step: 3,
        expected_diffs,
        expected_sum,
    }
}

/// An adversarial multi-tenant mix: one heavy tenant floods a burst of
/// distinct analytics programs while several light tenants each ask one
/// short query over the same table.
///
/// Every program is self-contained (it loads the shared values itself and
/// broadcasts its own threshold), so ANY admission interleaving across
/// tenants must reproduce each program's solo outputs — exactly the shape
/// the serving fairness tests need: under FIFO the heavy burst starves
/// the light tenants' latency, under weighted fair queueing it must not,
/// and bit-identity stays checkable program-by-program either way.
#[derive(Clone, Debug)]
pub struct HeavyTenantScenario {
    /// `(tenant, program)` in submission order: the heavy tenant's whole
    /// burst first, then one program per light tenant.
    pub submissions: Vec<(usize, Program)>,
    /// Shared record values every program loads.
    pub values: Vec<u64>,
    pub heavy_tenant: usize,
    pub light_tenants: usize,
    /// Per-submission filter threshold (distinct per program, so the
    /// heavy burst cannot be answered from the cache).
    pub thresholds: Vec<u64>,
    /// Per-submission ground truth for the filter step.
    pub expected_matches: Vec<Vec<usize>>,
    /// IR step index of the filter in every program.
    pub filter_step: usize,
}

/// Build the adversarial mix: `heavy_burst` programs for tenant 0 plus
/// one program for each of `light_tenants` tenants (ids `1..=light`).
pub fn heavy_tenant_scenario(
    cfg: &SimConfig,
    n_records: usize,
    seed: u64,
    heavy_burst: usize,
    light_tenants: usize,
) -> HeavyTenantScenario {
    assert!(n_records > 0 && heavy_burst > 0, "scenario needs work");
    let mask = if cfg.word_bits == 64 { u64::MAX } else { (1 << cfg.word_bits) - 1 };
    let pos_max = mask >> 1;
    let mut rng = Rng::new(seed);
    let values: Vec<u64> = (0..n_records).map(|_| rng.below(pos_max + 1)).collect();

    let program_for = |threshold: u64| {
        let mut p = Program::new(n_records);
        let t = p.scratch();
        let all = p.all();
        p.load(0, values.clone());
        p.broadcast(t, threshold);
        p.filter(all, t, Predicate::Lt);
        p.compare(all, t);
        p
    };
    let matches_for = |threshold: u64| -> Vec<usize> {
        values
            .iter()
            .enumerate()
            .filter(|(_, &v)| v < threshold)
            .map(|(i, _)| i)
            .collect()
    };

    let mut submissions = Vec::new();
    let mut thresholds = Vec::new();
    let mut expected_matches = Vec::new();
    // heavy burst: spread thresholds over the value range so each
    // program is distinct (no cache shortcut for the flood); u128
    // intermediates keep wide-word configs from overflowing
    let spread = |num: usize, den: usize| -> u64 {
        1 + ((pos_max as u128 * num as u128) / (den as u128 + 1)) as u64
    };
    for i in 0..heavy_burst {
        let threshold = spread(1 + i, heavy_burst);
        submissions.push((0, program_for(threshold)));
        thresholds.push(threshold);
        expected_matches.push(matches_for(threshold));
    }
    for t in 1..=light_tenants {
        let threshold = spread(t, light_tenants);
        submissions.push((t, program_for(threshold)));
        thresholds.push(threshold);
        expected_matches.push(matches_for(threshold));
    }

    HeavyTenantScenario {
        submissions,
        values,
        heavy_tenant: 0,
        light_tenants,
        thresholds,
        expected_matches,
        filter_step: 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::planner::ir::IrOp;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    #[test]
    fn scenario_is_valid_and_nondegenerate() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 100, 2026);
        s.program.validate(&cfg).unwrap();
        assert_eq!(s.values.len(), 100);
        assert!(matches!(s.program.ops[s.filter_step], IrOp::Filter { .. }));
        assert!(matches!(s.program.ops[s.compare_step], IrOp::Compare { .. }));
        assert!(matches!(s.program.ops[s.aggregate_step], IrOp::Aggregate { .. }));
        assert!(!s.expected_matches.is_empty(), "degenerate: no matches");
        assert!(s.expected_matches.len() < 100, "degenerate: all match");
        assert_eq!(s.values[s.expected_min_index], *s.values.iter().min().unwrap());
    }

    #[test]
    fn diff_scenario_shares_the_analytics_table() {
        let cfg = cfg();
        let a = analytics_scenario(&cfg, 60, 11);
        let d = diff_scenario(&cfg, 60, 11);
        assert_eq!(a.values, d.values, "same seed, same table");
        assert_eq!(a.threshold, d.reference, "same broadcast contents");
        assert!(matches!(d.program.ops[d.sub_step], IrOp::Sub { .. }));
        assert!(matches!(d.program.ops[d.aggregate_step], IrOp::Aggregate { .. }));
        d.program.validate(&cfg).unwrap();
        assert_eq!(d.expected_diffs[0], d.values[0] as i128 - d.reference as i128);
        assert_eq!(d.expected_sum, d.values.iter().map(|&v| v as u128).sum::<u128>());
    }

    #[test]
    fn heavy_tenant_scenario_is_adversarial_and_self_contained() {
        let cfg = cfg();
        let s = heavy_tenant_scenario(&cfg, 40, 3, 6, 3);
        assert_eq!(s.submissions.len(), 9);
        assert_eq!(s.thresholds.len(), 9);
        assert_eq!(s.expected_matches.len(), 9);
        // the burst comes first and belongs entirely to the heavy tenant
        assert!(s.submissions[..6].iter().all(|(t, _)| *t == s.heavy_tenant));
        let light: Vec<usize> = s.submissions[6..].iter().map(|(t, _)| *t).collect();
        assert_eq!(light, vec![1, 2, 3]);
        // distinct thresholds: the flood cannot be served from the cache
        let mut heavy_thresholds = s.thresholds[..6].to_vec();
        heavy_thresholds.dedup();
        assert_eq!(heavy_thresholds.len(), 6);
        for ((_, p), want) in s.submissions.iter().zip(&s.expected_matches) {
            p.validate(&cfg).unwrap();
            assert!(matches!(p.ops[s.filter_step], IrOp::Filter { .. }));
            // ground truth is consistent with the shared values
            let threshold = match &p.ops[1] {
                IrOp::Broadcast { value, .. } => *value,
                other => panic!("expected broadcast, got {other:?}"),
            };
            let host: Vec<usize> = s
                .values
                .iter()
                .enumerate()
                .filter(|(_, &v)| v < threshold)
                .map(|(i, _)| i)
                .collect();
            assert_eq!(&host, want);
        }
    }

    #[test]
    fn scenario_is_deterministic_per_seed() {
        let cfg = cfg();
        let a = analytics_scenario(&cfg, 50, 7);
        let b = analytics_scenario(&cfg, 50, 7);
        assert_eq!(a.values, b.values);
        assert_eq!(a.program, b.program);
        let c = analytics_scenario(&cfg, 50, 8);
        assert_ne!(a.values, c.values);
    }
}
