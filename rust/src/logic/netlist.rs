//! Structural gate netlists: the Fig. 1(d) and Fig. 3(d) schematics as
//! literal wired gates, evaluated combinationally.
//!
//! The behavioral modules in `modules.rs` are the fast path; these
//! netlists are the schematic-level ground truth.  Tests prove the two
//! agree on every input, and the netlist's critical-path depth feeds the
//! latency model's compute-module term (a sanity anchor for
//! `T_CIM_EXTRA_*` in `energy::constants`).

use std::collections::BTreeMap;

use super::gates::Gate;

/// A net (wire) by name.
pub type Net = &'static str;

/// One gate instance: output net, gate kind, input nets (a, b, c).
#[derive(Clone, Debug)]
pub struct Instance {
    pub out: Net,
    pub gate: Gate,
    pub ins: [Option<Net>; 3],
}

/// A combinational netlist over named nets.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    instances: Vec<Instance>,
}

impl Netlist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn gate(&mut self, out: Net, gate: Gate, ins: &[Net]) -> &mut Self {
        assert!(ins.len() <= 3 && !ins.is_empty());
        let mut arr = [None, None, None];
        for (i, n) in ins.iter().enumerate() {
            arr[i] = Some(*n);
        }
        self.instances.push(Instance { out, gate, ins: arr });
        self
    }

    /// Evaluate with the given primary-input assignment.  Instances must
    /// be in topological order (gates reference earlier nets) — asserted.
    pub fn eval(&self, inputs: &BTreeMap<Net, bool>) -> BTreeMap<Net, bool> {
        let mut nets = inputs.clone();
        for inst in &self.instances {
            let get = |n: Option<Net>| -> bool {
                match n {
                    None => false,
                    Some(name) => *nets
                        .get(name)
                        .unwrap_or_else(|| panic!("net {name} not yet driven")),
                }
            };
            let v = inst.gate.eval(get(inst.ins[0]), get(inst.ins[1]), get(inst.ins[2]));
            nets.insert(inst.out, v);
        }
        nets
    }

    /// Logic depth (gate levels) from primary inputs to `out`.
    pub fn depth_of(&self, out: Net) -> usize {
        let mut depth: BTreeMap<Net, usize> = BTreeMap::new();
        for inst in &self.instances {
            let d = inst
                .ins
                .iter()
                .flatten()
                .map(|n| depth.get(n).copied().unwrap_or(0))
                .max()
                .unwrap_or(0);
            depth.insert(inst.out, d + 1);
        }
        depth.get(out).copied().unwrap_or(0)
    }

    pub fn gate_count(&self) -> usize {
        self.instances.len()
    }
}

/// The Fig. 3(d) muxed ADRA compute module as a literal netlist.
///
/// Primary inputs: `or`, `or_n`, `and_n`, `b`, `sel`, `sel_n`, `cin`
/// (complements come free from the differential SAs / select inverter).
/// Outputs: `sum`, `carry`.
pub fn adra_module_netlist() -> Netlist {
    let mut n = Netlist::new();
    // X = A^B = OR . !AND ; XNOR = !X
    n.gate("x", Gate::And2, &["or", "and_n"]);
    n.gate("x_n", Gate::Not, &["x"]);
    // generate terms: add -> AND (primary), sub -> A.!B = NOR(!OR, B)
    n.gate("and", Gate::Not, &["and_n"]);
    n.gate("gen_sub", Gate::Nor2, &["or_n", "b"]);
    // select muxes (sel=1 -> subtraction datapath)
    n.gate("prop", Gate::Mux2, &["x", "x_n", "sel"]);
    n.gate("gen", Gate::Mux2, &["and", "gen_sub", "sel"]);
    // sum and carry
    n.gate("sum", Gate::Xor2, &["prop", "cin"]);
    n.gate("carry_n", Gate::Aoi21, &["cin", "prop", "gen"]);
    n.gate("carry", Gate::Not, &["carry_n"]);
    n
}

/// The Fig. 1(d) baseline adder module as a netlist.
pub fn baseline_module_netlist() -> Netlist {
    let mut n = Netlist::new();
    n.gate("x", Gate::And2, &["or", "and_n"]);
    n.gate("and", Gate::Not, &["and_n"]);
    n.gate("sum", Gate::Xor2, &["x", "cin"]);
    n.gate("carry_n", Gate::Aoi21, &["cin", "x", "and"]);
    n.gate("carry", Gate::Not, &["carry_n"]);
    n
}

/// The OAI21 A-recovery network (paper §III.A).
/// Inputs: `or`, `or_n`, `and_n`, `b`.  Output: `a`.
pub fn a_recovery_netlist() -> Netlist {
    let mut n = Netlist::new();
    n.gate("nor_ab", Gate::Not, &["or"]); // NOR(A,B) = !OR (complement free)
    n.gate("a", Gate::Oai21, &["b", "nor_ab", "and_n"]);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::modules::{AdraComputeModule, BaselineAddModule, ComputeModuleVariant};
    use crate::sensing::SenseOut;

    fn inputs(a: bool, b: bool, cin: bool, sel: bool) -> BTreeMap<Net, bool> {
        let or = a || b;
        let and = a && b;
        BTreeMap::from([
            ("or", or),
            ("or_n", !or),
            ("and_n", !and),
            ("b", b),
            ("cin", cin),
            ("sel", sel),
            ("sel_n", !sel),
        ])
    }

    #[test]
    fn adra_netlist_matches_behavioral_module_exhaustively() {
        let netlist = adra_module_netlist();
        let module = AdraComputeModule::new(ComputeModuleVariant::Muxed);
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    for sel in [false, true] {
                        let nets = netlist.eval(&inputs(a, b, cin, sel));
                        let s = SenseOut { or: a || b, b, and: a && b };
                        let want = module.eval(&s, cin, sel);
                        assert_eq!(nets["sum"], want.sum, "sum a={a} b={b} cin={cin} sel={sel}");
                        assert_eq!(
                            nets["carry"], want.carry,
                            "carry a={a} b={b} cin={cin} sel={sel}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn baseline_netlist_matches_behavioral_exhaustively() {
        let netlist = baseline_module_netlist();
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    let nets = netlist.eval(&inputs(a, b, cin, false));
                    let want = BaselineAddModule.eval(a || b, a && b, cin);
                    assert_eq!(nets["sum"], want.sum);
                    assert_eq!(nets["carry"], want.carry);
                }
            }
        }
    }

    #[test]
    fn a_recovery_netlist_truth_table() {
        let netlist = a_recovery_netlist();
        for a in [false, true] {
            for b in [false, true] {
                let nets = netlist.eval(&inputs(a, b, false, false));
                assert_eq!(nets["a"], a, "recovery failed at a={a} b={b}");
            }
        }
    }

    #[test]
    fn critical_path_depths_anchor_latency_model() {
        // ADRA module is at most 2 gate levels deeper than the baseline
        // module (mux stage + XNOR inverter off the critical path), which
        // is what justifies the small fixed T_CIM_EXTRA terms.
        let adra = adra_module_netlist();
        let base = baseline_module_netlist();
        let d_adra = adra.depth_of("carry").max(adra.depth_of("sum"));
        let d_base = base.depth_of("carry").max(base.depth_of("sum"));
        assert!(d_adra > d_base, "ADRA module must be deeper");
        assert!(
            d_adra - d_base <= 2,
            "depth delta {} too large for the latency calibration",
            d_adra - d_base
        );
        // ~100 ps/level at 45 nm x 32-bit ripple stays within the modeled
        // extra CiM latency budget (T_CIM_EXTRA ~ 0.13 ns covers module
        // entry; the ripple itself is shared with the baseline path)
        assert!(adra.gate_count() <= 12);
    }

    #[test]
    #[should_panic(expected = "not yet driven")]
    fn undriven_net_panics() {
        let mut n = Netlist::new();
        n.gate("y", Gate::Not, &["ghost"]);
        n.eval(&BTreeMap::new());
    }
}
