//! Per-column compute modules.
//!
//! * `BaselineAddModule` — Fig. 1(d): prior-work adder from the OR/AND
//!   sense outputs (commutative functions only).
//! * `AdraComputeModule` — Fig. 3(d): add/subtract module taking the third
//!   (B) sense output.  Two variants, as in the paper:
//!   - `Muxed`: two 2:1 muxes + NOT + NOR on top of the baseline module;
//!     SELECT chooses addition or subtraction (one function per cycle).
//!   - `Duplicated`: the muxes removed, one XOR + AOI21 duplicated so
//!     addition AND subtraction are produced in the same cycle
//!     (+4 transistors over `Muxed`).

use super::gates::{Gate, GateCounts};
use crate::sensing::SenseOut;

/// One module's combinational outputs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModuleOut {
    pub sum: bool,
    pub carry: bool,
}

/// Fig. 1(d): SUM/CARRY from OR, AND and carry-in.
///
/// A^B is reconstructed as OR & !AND; CARRY = AND | (Cin & (A^B)) via an
/// AOI21 + inverter.
#[derive(Clone, Copy, Debug, Default)]
pub struct BaselineAddModule;

impl BaselineAddModule {
    #[inline]
    pub fn eval(&self, or: bool, and: bool, cin: bool) -> ModuleOut {
        let x = Gate::And2.eval(or, !and, false); // A ^ B
        let sum = Gate::Xor2.eval(x, cin, false);
        let carry = !Gate::Aoi21.eval(cin, x, and); // AND | (Cin & X)
        ModuleOut { sum, carry }
    }

    pub fn gate_counts(&self) -> GateCounts {
        let mut g = GateCounts::new();
        g.add(Gate::And2, 1) // X = OR . !AND (complement free from SA)
            .add(Gate::Xor2, 1)
            .add(Gate::Aoi21, 1)
            .add(Gate::Not, 1);
        g
    }
}

/// Which Fig. 3(d) realization to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ComputeModuleVariant {
    /// SELECT-muxed add/sub (one function per cycle).
    Muxed,
    /// Duplicated XOR/AOI21 datapath (add and sub same cycle, +4T).
    Duplicated,
}

/// Fig. 3(d): the ADRA add/subtract compute module.
#[derive(Clone, Copy, Debug)]
pub struct AdraComputeModule {
    pub variant: ComputeModuleVariant,
}

impl AdraComputeModule {
    pub fn new(variant: ComputeModuleVariant) -> Self {
        Self { variant }
    }

    /// Propagate/generate for addition: prop = A^B, gen = A.B.
    #[inline]
    fn add_pg(s: &SenseOut) -> (bool, bool) {
        let x = Gate::And2.eval(s.or, !s.and, false);
        (x, s.and)
    }

    /// Propagate/generate for subtraction (A + !B + cin): prop = XNOR(A,B),
    /// gen = A.!B = NOR(!OR, B) — B and the complements come free from the
    /// differential sense amps.
    #[inline]
    fn sub_pg(s: &SenseOut) -> (bool, bool) {
        let x = Gate::And2.eval(s.or, !s.and, false);
        let prop = Gate::Not.eval(x, false, false); // XNOR via NOT(X)
        let gen = Gate::Nor2.eval(!s.or, s.b, false); // A . !B
        (prop, gen)
    }

    /// Muxed evaluation: `select` = false -> addition, true -> subtraction.
    #[inline]
    pub fn eval(&self, s: &SenseOut, cin: bool, select: bool) -> ModuleOut {
        let (pa, ga) = Self::add_pg(s);
        let (ps, gs) = Self::sub_pg(s);
        let prop = Gate::Mux2.eval(pa, ps, select);
        let gen = Gate::Mux2.eval(ga, gs, select);
        let sum = Gate::Xor2.eval(prop, cin, false);
        let carry = !Gate::Aoi21.eval(cin, prop, gen);
        ModuleOut { sum, carry }
    }

    /// Duplicated-datapath evaluation: both functions in the same cycle.
    /// Returns `(add, sub)`.
    #[inline]
    pub fn eval_both(&self, s: &SenseOut, cin_add: bool, cin_sub: bool) -> (ModuleOut, ModuleOut) {
        let (pa, ga) = Self::add_pg(s);
        let (ps, gs) = Self::sub_pg(s);
        let add = ModuleOut {
            sum: Gate::Xor2.eval(pa, cin_add, false),
            carry: !Gate::Aoi21.eval(cin_add, pa, ga),
        };
        let sub = ModuleOut {
            sum: Gate::Xor2.eval(ps, cin_sub, false),
            carry: !Gate::Aoi21.eval(cin_sub, ps, gs),
        };
        (add, sub)
    }

    /// Gate inventory (drives the overhead numbers reported in Fig. 3(d)'s
    /// discussion).  Mux2 is a 4T transmission-gate pair; the two muxes
    /// share one select inverter, counted as the extra `Not`.
    pub fn gate_counts(&self) -> GateCounts {
        let mut g = BaselineAddModule.gate_counts();
        match self.variant {
            ComputeModuleVariant::Muxed => {
                g.add(Gate::Mux2, 2) // prop mux + gen mux (4T each)
                    .add(Gate::Not, 2) // shared select inverter + XNOR inverter
                    .add(Gate::Nor2, 1); // A.!B generate term
            }
            ComputeModuleVariant::Duplicated => {
                g.add(Gate::Xor2, 1) // duplicated SUM xor
                    .add(Gate::Aoi21, 1) // duplicated carry AOI
                    .add(Gate::Not, 1) // XNOR inverter (carry inv shared)
                    .add(Gate::Nor2, 1); // A.!B generate term
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sense(a: bool, b: bool) -> SenseOut {
        SenseOut { or: a || b, b, and: a && b }
    }

    #[test]
    fn baseline_is_a_full_adder() {
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    let out = BaselineAddModule.eval(a || b, a && b, cin);
                    let expect = a as u8 + b as u8 + cin as u8;
                    assert_eq!(out.sum, expect & 1 == 1, "sum a={a} b={b} cin={cin}");
                    assert_eq!(out.carry, expect >= 2, "carry a={a} b={b} cin={cin}");
                }
            }
        }
    }

    #[test]
    fn adra_addition_matches_full_adder() {
        let m = AdraComputeModule::new(ComputeModuleVariant::Muxed);
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    let out = m.eval(&sense(a, b), cin, false);
                    let expect = a as u8 + b as u8 + cin as u8;
                    assert_eq!(out.sum, expect & 1 == 1);
                    assert_eq!(out.carry, expect >= 2);
                }
            }
        }
    }

    #[test]
    fn adra_subtraction_is_a_plus_notb() {
        let m = AdraComputeModule::new(ComputeModuleVariant::Muxed);
        for a in [false, true] {
            for b in [false, true] {
                for cin in [false, true] {
                    let out = m.eval(&sense(a, b), cin, true);
                    let expect = a as u8 + (!b) as u8 + cin as u8;
                    assert_eq!(out.sum, expect & 1 == 1, "a={a} b={b} cin={cin}");
                    assert_eq!(out.carry, expect >= 2, "a={a} b={b} cin={cin}");
                }
            }
        }
    }

    #[test]
    fn duplicated_variant_matches_muxed_on_both_functions() {
        let muxed = AdraComputeModule::new(ComputeModuleVariant::Muxed);
        let dup = AdraComputeModule::new(ComputeModuleVariant::Duplicated);
        for a in [false, true] {
            for b in [false, true] {
                for ca in [false, true] {
                    for cs in [false, true] {
                        let s = sense(a, b);
                        let (add, sub) = dup.eval_both(&s, ca, cs);
                        assert_eq!(add, muxed.eval(&s, ca, false));
                        assert_eq!(sub, muxed.eval(&s, cs, true));
                    }
                }
            }
        }
    }

    #[test]
    fn paper_overhead_claims() {
        let base = BaselineAddModule.gate_counts();
        let muxed = AdraComputeModule::new(ComputeModuleVariant::Muxed).gate_counts();
        let dup = AdraComputeModule::new(ComputeModuleVariant::Duplicated).gate_counts();
        // "two 2:1 multiplexers, one NOT and one NOR gate" (+ the mux
        // select inverter) over the prior compute module:
        assert_eq!(muxed.count(Gate::Mux2) - base.count(Gate::Mux2), 2);
        assert_eq!(muxed.count(Gate::Nor2) - base.count(Gate::Nor2), 1);
        assert!(muxed.count(Gate::Not) > base.count(Gate::Not));
        // "an overhead of 4 transistors (compared to the former design)":
        assert_eq!(dup.transistor_delta(&muxed), 4);
    }
}
