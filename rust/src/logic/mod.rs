//! Gate-level digital periphery: primitive gates with transistor-count
//! accounting, the baseline adder compute module (Fig. 1(d)), the ADRA
//! add/subtract compute module (Fig. 3(d), both variants), the ripple
//! carry chain with the (n+1)-th overflow module, and the AND-tree
//! equality comparator.

pub mod carry;
pub mod comparator;
pub mod gates;
pub mod modules;
pub mod netlist;

pub use carry::{ripple_add_sub, sense_from_bits, RippleResult};
pub use comparator::{and_tree_equal, compare, CompareResult};
pub use gates::{Gate, GateCounts};
pub use modules::{AdraComputeModule, BaselineAddModule, ComputeModuleVariant, ModuleOut};
