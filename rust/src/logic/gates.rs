//! Primitive gates with static-CMOS transistor counts.
//!
//! The counts drive the hardware-overhead accounting the paper reports
//! ("two 2:1 muxes, one NOT and one NOR more than prior compute modules";
//! "the duplicated-XOR variant costs 4 extra transistors").

/// Primitive gate kinds used by the periphery.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gate {
    Not,
    Nand2,
    Nor2,
    And2,
    Or2,
    Xor2,
    Xnor2,
    Mux2,
    Aoi21,
    Oai21,
}

impl Gate {
    /// Static-CMOS transistor count (standard-cell typical).
    pub fn transistors(&self) -> usize {
        match self {
            Gate::Not => 2,
            Gate::Nand2 | Gate::Nor2 => 4,
            Gate::And2 | Gate::Or2 => 6,
            Gate::Xor2 | Gate::Xnor2 => 8,   // transmission-gate XOR
            Gate::Mux2 => 4,                 // TG pair; select inverter counted separately
            Gate::Aoi21 | Gate::Oai21 => 6,
        }
    }

    /// Evaluate the gate (3-input forms take c; 2-input forms ignore it).
    pub fn eval(&self, a: bool, b: bool, c: bool) -> bool {
        match self {
            Gate::Not => !a,
            Gate::Nand2 => !(a && b),
            Gate::Nor2 => !(a || b),
            Gate::And2 => a && b,
            Gate::Or2 => a || b,
            Gate::Xor2 => a ^ b,
            Gate::Xnor2 => !(a ^ b),
            Gate::Mux2 => if c { b } else { a }, // c = select
            Gate::Aoi21 => !((a && b) || c),
            Gate::Oai21 => !((a || b) && c),
        }
    }
}

/// A tally of gates, used to cost a module in transistors.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GateCounts {
    counts: Vec<(Gate, usize)>,
}

impl GateCounts {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, gate: Gate, n: usize) -> &mut Self {
        for entry in self.counts.iter_mut() {
            if entry.0 == gate {
                entry.1 += n;
                return self;
            }
        }
        self.counts.push((gate, n));
        self
    }

    pub fn count(&self, gate: Gate) -> usize {
        self.counts
            .iter()
            .find(|(g, _)| *g == gate)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    }

    pub fn total_gates(&self) -> usize {
        self.counts.iter().map(|(_, n)| n).sum()
    }

    pub fn total_transistors(&self) -> usize {
        self.counts.iter().map(|(g, n)| g.transistors() * n).sum()
    }

    /// Transistor difference vs another tally (self - other).
    pub fn transistor_delta(&self, other: &GateCounts) -> isize {
        self.total_transistors() as isize - other.total_transistors() as isize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_truth_tables() {
        for a in [false, true] {
            for b in [false, true] {
                assert_eq!(Gate::Nand2.eval(a, b, false), !(a && b));
                assert_eq!(Gate::Nor2.eval(a, b, false), !(a || b));
                assert_eq!(Gate::Xor2.eval(a, b, false), a ^ b);
                assert_eq!(Gate::Xnor2.eval(a, b, false), !(a ^ b));
                for c in [false, true] {
                    assert_eq!(Gate::Mux2.eval(a, b, c), if c { b } else { a });
                    assert_eq!(Gate::Aoi21.eval(a, b, c), !((a && b) || c));
                    assert_eq!(Gate::Oai21.eval(a, b, c), !((a || b) && c));
                }
            }
        }
        assert!(Gate::Not.eval(false, false, false));
    }

    #[test]
    fn transistor_counts_sane() {
        assert_eq!(Gate::Not.transistors(), 2);
        assert_eq!(Gate::Nand2.transistors(), 4);
        assert_eq!(Gate::Xor2.transistors(), 8);
    }

    #[test]
    fn tally_accumulates() {
        let mut t = GateCounts::new();
        t.add(Gate::Xor2, 2).add(Gate::Not, 1).add(Gate::Xor2, 1);
        assert_eq!(t.count(Gate::Xor2), 3);
        assert_eq!(t.total_gates(), 4);
        assert_eq!(t.total_transistors(), 3 * 8 + 2);
    }

    #[test]
    fn delta_computation() {
        let mut a = GateCounts::new();
        a.add(Gate::Xor2, 1);
        let mut b = GateCounts::new();
        b.add(Gate::Not, 1);
        assert_eq!(a.transistor_delta(&b), 6);
        assert_eq!(b.transistor_delta(&a), -6);
    }
}
