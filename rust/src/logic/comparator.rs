//! In-memory comparison via subtraction (paper §III.B): the sign bit of
//! the (n+1)-bit A-B output orders the operands; an AND tree over the
//! inverted sum bits detects equality with n-1 two-input AND gates.

use super::carry::{ripple_add_sub, RippleResult};
use crate::sensing::SenseOut;

/// Three-way comparison outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompareResult {
    Less,
    Equal,
    Greater,
}

/// AND-tree equality detect over the subtraction output bits: true iff
/// every bit is zero.  Mirrors the gate tree (inverters assumed free from
/// the module's complementary outputs; n-1 AND2 gates for n inputs).
pub fn and_tree_equal(bits: &[bool]) -> bool {
    // literal tree reduction, as the hardware would wire it
    let mut level: Vec<bool> = bits.iter().map(|&b| !b).collect();
    while level.len() > 1 {
        level = level
            .chunks(2)
            .map(|c| if c.len() == 2 { c[0] && c[1] } else { c[0] })
            .collect();
    }
    level[0]
}

/// Full comparison from per-bit sense outputs (two's-complement operands).
pub fn compare(sense_bits: &[SenseOut]) -> (CompareResult, RippleResult) {
    let diff = ripple_add_sub(sense_bits, true);
    let res = if and_tree_equal(&diff.bits) {
        CompareResult::Equal
    } else if diff.sign() {
        CompareResult::Less
    } else {
        CompareResult::Greater
    };
    (res, diff)
}

/// Number of AND2 gates in the equality tree for an n-bit comparison
/// ("n-1 two-input AND gates ... just 1 gate per bit of comparison").
pub fn and_tree_gate_count(n_bits: usize) -> usize {
    n_bits.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::carry::sense_from_bits;

    fn signed(v: u64, bits: usize) -> i64 {
        let m = if bits == 64 { u64::MAX } else { (1 << bits) - 1 };
        let raw = (v & m) as i64;
        if (v >> (bits - 1)) & 1 == 1 {
            raw - (1i64 << bits)
        } else {
            raw
        }
    }

    #[test]
    fn exhaustive_5bit_compare() {
        for a in 0u64..32 {
            for b in 0u64..32 {
                let (res, _) = compare(&sense_from_bits(a, b, 5));
                let (sa, sb) = (signed(a, 5), signed(b, 5));
                let expect = match sa.cmp(&sb) {
                    std::cmp::Ordering::Less => CompareResult::Less,
                    std::cmp::Ordering::Equal => CompareResult::Equal,
                    std::cmp::Ordering::Greater => CompareResult::Greater,
                };
                assert_eq!(res, expect, "a={sa} b={sb}");
            }
        }
    }

    #[test]
    fn and_tree_matches_all_zero() {
        assert!(and_tree_equal(&[false; 7]));
        assert!(and_tree_equal(&[false]));
        assert!(!and_tree_equal(&[false, true, false]));
        assert!(!and_tree_equal(&[true]));
    }

    #[test]
    fn and_tree_odd_and_even_widths() {
        for n in 1..=16 {
            let mut v = vec![false; n];
            assert!(and_tree_equal(&v), "width {n}");
            v[n - 1] = true;
            assert!(!and_tree_equal(&v), "width {n}");
            v[n - 1] = false;
            if n > 1 {
                v[0] = true;
                assert!(!and_tree_equal(&v), "width {n}");
            }
        }
    }

    #[test]
    fn gate_count_is_n_minus_one() {
        assert_eq!(and_tree_gate_count(32), 31);
        assert_eq!(and_tree_gate_count(1), 0);
    }

    #[test]
    fn equality_is_detected_not_inferred_from_sign() {
        // A == B must report Equal even though sign would say "not less"
        let (res, diff) = compare(&sense_from_bits(13, 13, 8));
        assert_eq!(res, CompareResult::Equal);
        assert!(diff.is_zero());
    }
}
