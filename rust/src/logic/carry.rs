//! Ripple carry chain over the per-column compute modules.
//!
//! n+1 modules serve every n-bit add/subtract (paper §III.B): the extra
//! module absorbs overflow; for subtraction its inputs are the
//! sign-extended operands, i.e. the same sense outputs as bit n-1, and the
//! result is an (n+1)-bit two's-complement value whose MSB is the sign.

use super::modules::{AdraComputeModule, ComputeModuleVariant};
use crate::sensing::SenseOut;

/// Result of an n-bit ripple add/sub: (n+1)-bit value + raw carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RippleResult {
    /// sum bits, LSB first; length n+1.
    pub bits: Vec<bool>,
    /// carry out of each module; length n+1.
    pub carries: Vec<bool>,
}

impl RippleResult {
    /// Interpret as unsigned (addition result).
    pub fn as_unsigned(&self) -> u128 {
        self.bits
            .iter()
            .enumerate()
            .fold(0u128, |acc, (i, &b)| acc | ((b as u128) << i))
    }

    /// Interpret as two's-complement signed (subtraction result).
    pub fn as_signed(&self) -> i128 {
        let n = self.bits.len();
        let raw = self.as_unsigned() as i128;
        if self.bits[n - 1] {
            raw - (1i128 << n)
        } else {
            raw
        }
    }

    /// The sign bit — MSB of the (n+1)-bit output.
    pub fn sign(&self) -> bool {
        *self.bits.last().expect("non-empty result")
    }

    /// All-zero output (equality detect input).
    pub fn is_zero(&self) -> bool {
        self.bits.iter().all(|&b| !b)
    }
}

/// Ripple an n-bit word of sense outputs through n+1 ADRA compute modules.
///
/// `subtract = false` computes A + B (C_in = 0); `subtract = true`
/// computes A - B (C_in = 1, inverted-B datapath inside the module).
pub fn ripple_add_sub(sense_bits: &[SenseOut], subtract: bool) -> RippleResult {
    assert!(!sense_bits.is_empty(), "empty operand");
    let module = AdraComputeModule::new(ComputeModuleVariant::Muxed);
    let n = sense_bits.len();
    let mut bits = Vec::with_capacity(n + 1);
    let mut carries = Vec::with_capacity(n + 1);
    let mut cin = subtract; // C_in = 1 for subtraction (two's complement)
    for s in sense_bits {
        let out = module.eval(s, cin, subtract);
        bits.push(out.sum);
        carries.push(out.carry);
        cin = out.carry;
    }
    // (n+1)-th module: sign-extended inputs = same sense outputs as bit n-1
    // for subtraction; for addition the extension bit is 0 for both words.
    let ext = if subtract {
        sense_bits[n - 1]
    } else {
        SenseOut { or: false, b: false, and: false }
    };
    let out = module.eval(&ext, cin, subtract);
    bits.push(out.sum);
    carries.push(out.carry);
    RippleResult { bits, carries }
}

/// Expand a word's bits into ideal sense outputs — used by tests and by
/// the baseline engine, where A and B were read digitally.
pub fn sense_from_bits(a: u64, b: u64, n_bits: usize) -> Vec<SenseOut> {
    (0..n_bits)
        .map(|i| {
            let ab = (a >> i) & 1 == 1;
            let bb = (b >> i) & 1 == 1;
            SenseOut { or: ab || bb, b: bb, and: ab && bb }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{Arbitrary, Quick};
    use crate::util::rng::Rng;

    fn sign_extend(v: u64, bits: usize) -> i128 {
        let raw = (v & mask(bits)) as i128;
        if bits < 64 && (v >> (bits - 1)) & 1 == 1 {
            raw - (1i128 << bits)
        } else if bits == 64 && (v >> 63) & 1 == 1 {
            raw - (1i128 << 64)
        } else {
            raw
        }
    }

    fn mask(bits: usize) -> u64 {
        if bits == 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        }
    }

    #[test]
    fn exhaustive_4bit_addition() {
        for a in 0u64..16 {
            for b in 0u64..16 {
                let r = ripple_add_sub(&sense_from_bits(a, b, 4), false);
                assert_eq!(r.as_unsigned(), (a + b) as u128, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn exhaustive_4bit_subtraction_signed() {
        for a in 0u64..16 {
            for b in 0u64..16 {
                let r = ripple_add_sub(&sense_from_bits(a, b, 4), true);
                // operands are two's-complement 4-bit; result is 5-bit signed
                let expect = sign_extend(a, 4) - sign_extend(b, 4);
                assert_eq!(r.as_signed(), expect, "a={a} b={b} bits={:?}", r.bits);
            }
        }
    }

    #[test]
    fn exhaustive_6bit_subtraction() {
        for a in 0u64..64 {
            for b in 0u64..64 {
                let r = ripple_add_sub(&sense_from_bits(a, b, 6), true);
                assert_eq!(r.as_signed(), sign_extend(a, 6) - sign_extend(b, 6));
            }
        }
    }

    /// Random word widths and operands for the property tests.
    #[derive(Clone, Debug)]
    struct WordPair {
        a: u64,
        b: u64,
        bits: usize,
    }

    impl Arbitrary for WordPair {
        fn generate(rng: &mut Rng) -> Self {
            let bits = rng.range_u64(1, 63) as usize;
            Self {
                a: rng.next_u64() & mask(bits),
                b: rng.next_u64() & mask(bits),
                bits,
            }
        }

        fn shrink(&self) -> Vec<Self> {
            let mut v = Vec::new();
            if self.bits > 1 {
                v.push(Self {
                    a: self.a & mask(self.bits - 1),
                    b: self.b & mask(self.bits - 1),
                    bits: self.bits - 1,
                });
            }
            if self.a > 0 {
                v.push(Self { a: self.a / 2, ..self.clone() });
            }
            if self.b > 0 {
                v.push(Self { b: self.b / 2, ..self.clone() });
            }
            v
        }
    }

    #[test]
    fn prop_addition_matches_integer_add() {
        Quick::with_cases(500).check::<WordPair, _>("ripple add == +", |w| {
            let r = ripple_add_sub(&sense_from_bits(w.a, w.b, w.bits), false);
            r.as_unsigned() == (w.a as u128) + (w.b as u128)
        });
    }

    #[test]
    fn prop_subtraction_matches_integer_sub() {
        Quick::with_cases(500).check::<WordPair, _>("ripple sub == -", |w| {
            let r = ripple_add_sub(&sense_from_bits(w.a, w.b, w.bits), true);
            r.as_signed() == sign_extend(w.a, w.bits) - sign_extend(w.b, w.bits)
        });
    }

    #[test]
    fn prop_a_minus_a_is_zero() {
        Quick::with_cases(300).check::<WordPair, _>("a - a == 0", |w| {
            let r = ripple_add_sub(&sense_from_bits(w.a, w.a, w.bits), true);
            r.is_zero() && !r.sign()
        });
    }

    #[test]
    fn result_width_is_n_plus_one() {
        let r = ripple_add_sub(&sense_from_bits(5, 3, 8), false);
        assert_eq!(r.bits.len(), 9);
        assert_eq!(r.carries.len(), 9);
    }
}
