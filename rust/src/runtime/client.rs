//! The PJRT execution layer: compile each HLO-text artifact once on the
//! CPU client, cache the loaded executables, and expose typed wrappers
//! for every entry point.  `PjrtBackend` adapts the runtime to the
//! engine's `AnalogBackend` interface so the ADRA engine can run its
//! analog evaluations through the real JAX/Pallas-lowered computation.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

use super::artifact::{ArtifactManifest, EntryPoint};
use crate::cim::AnalogBackend;
use crate::config::{N_COLS, N_SWEEP};

/// Compiled-executable cache over the PJRT CPU client.
pub struct AnalogRuntime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    executables: HashMap<EntryPoint, xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla wrappers hold `Rc<PjRtClientInternal>` handles, which are
// not `Send` by construction.  Every `Rc` clone in this runtime (the client
// plus the per-executable back-references) lives inside this one struct and
// is only ever used by the thread that currently owns the `AnalogRuntime`;
// the struct is moved whole into a coordinator worker and never shared, so
// the non-atomic refcounts are never touched from two threads.  The PJRT
// CPU client itself is thread-confined under this ownership discipline.
unsafe impl Send for AnalogRuntime {}

impl AnalogRuntime {
    /// Create a runtime over the given artifact directory, compiling
    /// every entry point eagerly (compile once, execute many).
    pub fn new(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut rt = Self { client, manifest, executables: HashMap::new() };
        for ep in EntryPoint::ALL {
            rt.compile(ep)?;
        }
        Ok(rt)
    }

    /// Runtime from `$ADRA_ARTIFACTS` / `./artifacts`.
    pub fn from_default_artifacts() -> Result<Self> {
        let manifest = ArtifactManifest::load_default().map_err(|e| anyhow!(e))?;
        Self::new(manifest)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, ep: EntryPoint) -> Result<()> {
        let path = self.manifest.path_of(ep).map_err(|e| anyhow!(e))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text for {}", ep.name()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", ep.name()))?;
        self.executables.insert(ep, exe);
        Ok(())
    }

    /// Execute an entry point on literal inputs; returns the flattened
    /// tuple outputs.
    pub fn execute(&self, ep: EntryPoint, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(&ep)
            .ok_or_else(|| anyhow!("entry point {} not compiled", ep.name()))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", ep.name()))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        out.to_tuple().map_err(|e| anyhow!("decomposing result tuple: {e}"))
    }

    // ---- typed entry-point wrappers ---------------------------------------

    /// DC senseline currents: returns (i_sl, i_a, i_b), each `N_COLS` long.
    pub fn dc_isl(
        &self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f32,
        vg2: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let inputs = vec![
            cols_literal(pol_a)?,
            cols_literal(pol_b)?,
            cols_literal(dvt_a)?,
            cols_literal(dvt_b)?,
            xla::Literal::scalar(vg1),
            xla::Literal::scalar(vg2),
        ];
        let out = self.execute(EntryPoint::DcIsl, &inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("dc_isl: expected 3 outputs, got {}", out.len()));
        }
        Ok((
            out[0].to_vec::<f32>()?,
            out[1].to_vec::<f32>()?,
            out[2].to_vec::<f32>()?,
        ))
    }

    /// RBL discharge transient: returns (v_final, q_drawn, e_diss); the
    /// full [n_steps, N_COLS] trace is also available as `.0`.
    #[allow(clippy::too_many_arguments)]
    pub fn transient_cim(
        &self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f32,
        vg2: f32,
        v0: f32,
        c_rbl: f32,
    ) -> Result<TransientOut> {
        let inputs = vec![
            cols_literal(pol_a)?,
            cols_literal(pol_b)?,
            cols_literal(dvt_a)?,
            cols_literal(dvt_b)?,
            xla::Literal::scalar(vg1),
            xla::Literal::scalar(vg2),
            xla::Literal::scalar(v0),
            xla::Literal::scalar(c_rbl),
        ];
        let out = self.execute(EntryPoint::TransientCim, &inputs)?;
        if out.len() != 4 {
            return Err(anyhow!("transient_cim: expected 4 outputs, got {}", out.len()));
        }
        Ok(TransientOut {
            v_trace: out[0].to_vec::<f32>()?,
            v_final: out[1].to_vec::<f32>()?,
            q_drawn: out[2].to_vec::<f32>()?,
            e_diss: out[3].to_vec::<f32>()?,
        })
    }

    /// I-V hysteresis sweep (Fig. 2(c)): returns (i_d, pol) per point.
    pub fn iv_sweep(&self, vg_trace: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        if vg_trace.len() != N_SWEEP {
            return Err(anyhow!("iv_sweep wants {N_SWEEP} points, got {}", vg_trace.len()));
        }
        let out = self.execute(EntryPoint::IvSweep, &[xla::Literal::vec1(vg_trace)])?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?))
    }

    /// Write transient: polarization planes under a shared gate waveform.
    pub fn write_transient(&self, pol0: &[f32], vg_pulse: &[f32]) -> Result<Vec<f32>> {
        if vg_pulse.len() != N_SWEEP {
            return Err(anyhow!("write_transient wants {N_SWEEP} waveform points"));
        }
        let out = self.execute(
            EntryPoint::WriteTransient,
            &[cols_literal(pol0)?, xla::Literal::vec1(vg_pulse)],
        )?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Read-disturb trajectory: final polarization after sustained read.
    pub fn read_disturb(&self, pol0: &[f32]) -> Result<Vec<f32>> {
        let out = self.execute(EntryPoint::ReadDisturb, &[cols_literal(pol0)?])?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

/// Output bundle of the transient entry point.
#[derive(Clone, Debug)]
pub struct TransientOut {
    /// Flattened [n_steps * N_COLS] voltage trajectory.
    pub v_trace: Vec<f32>,
    pub v_final: Vec<f32>,
    pub q_drawn: Vec<f32>,
    pub e_diss: Vec<f32>,
}

/// Pad/validate a column plane to the artifact's static width.
fn cols_literal(data: &[f32]) -> Result<xla::Literal> {
    if data.len() == N_COLS {
        return Ok(xla::Literal::vec1(data));
    }
    if data.len() > N_COLS {
        return Err(anyhow!("plane wider than artifact width {N_COLS}"));
    }
    let mut padded = data.to_vec();
    padded.resize(N_COLS, 0.0);
    Ok(xla::Literal::vec1(&padded))
}

/// `AnalogBackend` adapter: the ADRA engine's analog evaluations served
/// by the compiled JAX/Pallas artifacts.  Narrow activations are padded
/// to the artifact width and sliced back.
pub struct PjrtBackend {
    rt: AnalogRuntime,
}

impl PjrtBackend {
    pub fn new(rt: AnalogRuntime) -> Self {
        Self { rt }
    }

    pub fn runtime(&self) -> &AnalogRuntime {
        &self.rt
    }
}

impl AnalogBackend for PjrtBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let n = pol_a.len();
        let (isl, _, _) = self
            .rt
            .dc_isl(pol_a, pol_b, dvt_a, dvt_b, vg1 as f32, vg2 as f32)
            .expect("PJRT dc_isl execution failed");
        isl[..n].iter().map(|&x| x as f64).collect()
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let n = pol_a.len();
        let out = self
            .rt
            .transient_cim(
                pol_a,
                pol_b,
                dvt_a,
                dvt_b,
                vg1 as f32,
                vg2 as f32,
                1.0, // V_READ precharge; engines use the configured device value
                c_rbl as f32,
            )
            .expect("PJRT transient execution failed");
        out.v_final[..n].iter().map(|&x| x as f64).collect()
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
