//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//! Python never runs here — the artifacts are self-contained.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactManifest, EntryPoint};
pub use client::{AnalogRuntime, PjrtBackend};
