//! Artifact manifest: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.  The manifest lists every lowered entry point and
//! its input signature; the static shapes here must match
//! `config::{N_COLS, N_SWEEP}`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::{N_COLS, N_SWEEP};

/// The five AOT entry points.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EntryPoint {
    DcIsl,
    TransientCim,
    IvSweep,
    WriteTransient,
    ReadDisturb,
}

impl EntryPoint {
    pub const ALL: [EntryPoint; 5] = [
        EntryPoint::DcIsl,
        EntryPoint::TransientCim,
        EntryPoint::IvSweep,
        EntryPoint::WriteTransient,
        EntryPoint::ReadDisturb,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EntryPoint::DcIsl => "dc_isl",
            EntryPoint::TransientCim => "transient_cim",
            EntryPoint::IvSweep => "iv_sweep",
            EntryPoint::WriteTransient => "write_transient",
            EntryPoint::ReadDisturb => "read_disturb",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|e| e.name() == s)
    }

    /// Expected input shapes (`None` = scalar), mirroring aot.ENTRY_POINTS.
    pub fn input_shapes(&self) -> Vec<Option<usize>> {
        let n = Some(N_COLS);
        let t = Some(N_SWEEP);
        match self {
            EntryPoint::DcIsl => vec![n, n, n, n, None, None],
            EntryPoint::TransientCim => vec![n, n, n, n, None, None, None, None],
            EntryPoint::IvSweep => vec![t],
            EntryPoint::WriteTransient => vec![n, t],
            EntryPoint::ReadDisturb => vec![n],
        }
    }
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    dir: PathBuf,
    entries: BTreeMap<String, PathBuf>,
}

impl ArtifactManifest {
    /// Load and validate the manifest in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split('\t');
            let name = parts.next().ok_or("manifest: missing name")?.to_string();
            let file = parts.next().ok_or("manifest: missing file")?;
            let fpath = dir.join(file);
            if !fpath.exists() {
                return Err(format!("manifest entry {name}: missing file {}", fpath.display()));
            }
            entries.insert(name, fpath);
        }
        let m = Self { dir, entries };
        // every known entry point must be present
        for ep in EntryPoint::ALL {
            m.path_of(ep)?;
        }
        Ok(m)
    }

    /// Default artifact location: `$ADRA_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self, String> {
        let dir = std::env::var("ADRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_of(&self, ep: EntryPoint) -> Result<&Path, String> {
        self.entries
            .get(ep.name())
            .map(|p| p.as_path())
            .ok_or_else(|| format!("manifest missing entry point {}", ep.name()))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_point_names_roundtrip() {
        for ep in EntryPoint::ALL {
            assert_eq!(EntryPoint::from_name(ep.name()), Some(ep));
        }
        assert_eq!(EntryPoint::from_name("bogus"), None);
    }

    #[test]
    fn input_shapes_match_abi() {
        assert_eq!(EntryPoint::DcIsl.input_shapes().len(), 6);
        assert_eq!(EntryPoint::TransientCim.input_shapes().len(), 8);
        assert_eq!(EntryPoint::IvSweep.input_shapes(), vec![Some(N_SWEEP)]);
    }

    #[test]
    fn missing_dir_is_a_helpful_error() {
        let err = ArtifactManifest::load("/nonexistent/nowhere").unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn loads_real_artifacts_when_present() {
        // exercised properly by the integration tests; here only when the
        // default dir exists (e.g. under `make test`)
        if std::path::Path::new("artifacts/manifest.txt").exists() {
            let m = ArtifactManifest::load("artifacts").unwrap();
            assert!(m.names().count() >= 5);
        }
    }
}
