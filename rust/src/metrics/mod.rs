//! Run metrics: counters, latency histogram, and aggregated energy — what
//! the coordinator and the end-to-end examples report.

use crate::energy::{EnergyBreakdown, OpCost};

/// Log-bucketed latency histogram (nanosecond ops up to seconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket 0 covers [0, 2) ns (every sub-nanosecond sample lands there
    /// together with the [1, 2) ns ones); bucket i >= 1 covers
    /// [2^i, 2^(i+1)) ns; the last bucket absorbs everything above its
    /// lower edge.  See `bucket_bounds`.
    buckets: Vec<u64>,
    count: u64,
    sum_ns: f64,
    max_ns: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: vec![0; LatencyHistogram::NUM_BUCKETS],
            count: 0,
            sum_ns: 0.0,
            max_ns: 0.0,
        }
    }
}

impl LatencyHistogram {
    /// Number of buckets (fixed; the last one is open-ended).
    pub const NUM_BUCKETS: usize = 40;

    /// The [lo, hi) nanosecond range bucket `i` covers.  Bucket 0 is
    /// [0, 2) — NOT [2^0, 2^1) — because `record` floors sub-nanosecond
    /// samples into the first bucket; the last bucket's upper edge is
    /// reported as infinity since it absorbs all larger samples.
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        assert!(i < Self::NUM_BUCKETS, "bucket {i} out of range");
        let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
        let hi = if i + 1 == Self::NUM_BUCKETS {
            f64::INFINITY
        } else {
            (1u64 << (i + 1)) as f64
        };
        (lo, hi)
    }

    pub fn record(&mut self, seconds: f64) {
        let ns = seconds * 1e9;
        let idx = if ns < 1.0 {
            0
        } else {
            (ns.log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns / self.count as f64
        }
    }

    /// Exact sum of all recorded samples (ns) — unlike the percentiles,
    /// this is not bucket-quantized.
    pub fn sum_ns(&self) -> f64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> f64 {
        self.max_ns
    }

    /// Approximate percentile from the log buckets (upper bound of the
    /// bucket containing the p-th sample).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Aggregated metrics for a stream of operations.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub ops: u64,
    pub errors: u64,
    pub energy: EnergyBreakdown,
    pub model_latency: LatencyHistogram,
    /// Wall-clock time of the run (set by the driver).
    pub wall_seconds: f64,
    /// Array access counters snapshotted from the engine(s) at collection
    /// time (`Engine::array_stats`) — includes the per-tier activation
    /// split of the tiered activation kernel.
    pub array: crate::array::ArrayStats,
}

impl RunMetrics {
    pub fn record(&mut self, cost: &OpCost) {
        self.ops += 1;
        self.energy = self.energy.add(&cost.energy);
        self.model_latency.record(cost.latency);
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &RunMetrics) {
        self.ops += other.ops;
        self.errors += other.errors;
        self.energy = self.energy.add(&other.energy);
        self.model_latency.merge(&other.model_latency);
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.array = self.array.merged(&other.array);
    }

    /// Modeled ops/s implied by the summed device latency.
    pub fn modeled_throughput(&self) -> f64 {
        let total_s = self.model_latency.mean_ns() * 1e-9 * self.ops as f64;
        if total_s > 0.0 {
            self.ops as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: {} ops ({} errors), modeled energy {:.3} nJ, \
             mean op latency {:.3} ns, p50/p95/p99 {:.0}/{:.0}/{:.0} ns, \
             modeled throughput {:.2} Mop/s, \
             activations {} ({} digital, {} masked, det cols {:.1}%), \
             wall {:.3} s",
            self.ops,
            self.errors,
            self.energy.total() * 1e9,
            self.model_latency.mean_ns(),
            self.model_latency.percentile_ns(50.0),
            self.model_latency.percentile_ns(95.0),
            self.model_latency.percentile_ns(99.0),
            self.modeled_throughput() / 1e6,
            self.array.dual_activations,
            self.array.digital_activations,
            self.array.masked_activations,
            self.array.det_col_fraction() * 100.0,
            self.wall_seconds,
        )
    }

    /// The total modeled cost this run accumulated (energy summed, latency
    /// summed serially) — what the planner's predictions are checked
    /// against.
    pub fn total_cost(&self) -> OpCost {
        OpCost {
            energy: self.energy,
            latency: self.model_latency.sum_ns() * 1e-9,
        }
    }
}

/// Predicted-vs-measured cost comparison: the planner predicts a program's
/// cost from its tables at lowering time; execution measures it through
/// the engines' per-op accounting.  Relative errors are signed
/// (positive = over-prediction).
#[derive(Clone, Copy, Debug)]
pub struct PredictionReport {
    pub predicted: OpCost,
    pub measured: OpCost,
}

impl PredictionReport {
    pub fn new(predicted: OpCost, measured: OpCost) -> Self {
        Self { predicted, measured }
    }

    fn rel(predicted: f64, measured: f64) -> f64 {
        if measured == 0.0 {
            if predicted == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (predicted - measured) / measured
        }
    }

    /// (predicted - measured) / measured on total energy.
    pub fn energy_error(&self) -> f64 {
        Self::rel(self.predicted.energy.total(), self.measured.energy.total())
    }

    /// (predicted - measured) / measured on summed latency.
    pub fn latency_error(&self) -> f64 {
        Self::rel(self.predicted.latency, self.measured.latency)
    }

    /// Are both errors within +-tol (e.g. 0.2 for 20%)?
    pub fn within(&self, tol: f64) -> bool {
        self.energy_error().abs() <= tol && self.latency_error().abs() <= tol
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: predicted {:.3} nJ / {:.1} ns vs measured {:.3} nJ / {:.1} ns \
             (energy err {:+.2}%, latency err {:+.2}%)",
            self.predicted.energy.total() * 1e9,
            self.predicted.latency * 1e9,
            self.measured.energy.total() * 1e9,
            self.measured.latency * 1e9,
            self.energy_error() * 100.0,
            self.latency_error() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(ns: f64) -> OpCost {
        OpCost {
            energy: EnergyBreakdown { rbl: 1e-15, ..Default::default() },
            latency: ns * 1e-9,
        }
    }

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = LatencyHistogram::default();
        for ns in [1.0, 2.0, 4.0, 8.0] {
            h.record(ns * 1e-9);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ns() - 3.75).abs() < 1e-9);
        assert_eq!(h.max_ns(), 8.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-9);
        }
        assert!(h.percentile_ns(50.0) <= h.percentile_ns(99.0));
        assert!(h.percentile_ns(99.0) >= 512.0);
    }

    #[test]
    fn metrics_accumulate_and_merge() {
        let mut m1 = RunMetrics::default();
        m1.record(&cost(2.0));
        m1.record(&cost(4.0));
        let mut m2 = RunMetrics::default();
        m2.record(&cost(8.0));
        m2.record_error();
        m1.merge(&m2);
        assert_eq!(m1.ops, 3);
        assert_eq!(m1.errors, 1);
        assert!((m1.energy.total() - 3e-15).abs() < 1e-25);
    }

    #[test]
    fn report_is_informative() {
        let mut m = RunMetrics::default();
        m.record(&cost(3.0));
        let r = m.report("test");
        assert!(r.contains("1 ops"));
        assert!(r.contains("test"));
        // tail-latency line: one 3 ns sample lands in bucket [2, 4), so
        // every percentile reports the 4 ns bucket upper bound
        assert!(r.contains("p50/p95/p99 4/4/4 ns"), "{r}");
    }

    /// Pin the bucket edges: bucket 0 is [0, 2) ns (doc/code mismatch fix
    /// — `record` floors log2, so 1.0 ns and 1.9 ns BOTH land in bucket 0
    /// alongside sub-ns samples), bucket i >= 1 is [2^i, 2^(i+1)), and the
    /// last bucket clamps.
    #[test]
    fn bucket_edges_pinned() {
        assert_eq!(LatencyHistogram::bucket_bounds(0), (0.0, 2.0));
        assert_eq!(LatencyHistogram::bucket_bounds(1), (2.0, 4.0));
        assert_eq!(LatencyHistogram::bucket_bounds(5), (32.0, 64.0));
        let (lo, hi) = LatencyHistogram::bucket_bounds(LatencyHistogram::NUM_BUCKETS - 1);
        assert_eq!(lo, (1u64 << 39) as f64);
        assert!(hi.is_infinite());

        let mut h = LatencyHistogram::default();
        // (sample ns, expected bucket): edges exercised on both sides
        let cases = [
            (0.25, 0usize),
            (1.0, 0),
            (1.99, 0),
            (2.0, 1),
            (3.99, 1),
            (4.0, 2),
            (32.0, 5),
            (63.9, 5),
            (1e12, LatencyHistogram::NUM_BUCKETS - 1), // 2^39.9 ns: clamped
        ];
        for &(ns, bucket) in &cases {
            h.record(ns * 1e-9);
            let (lo, hi) = LatencyHistogram::bucket_bounds(bucket);
            assert!(ns >= lo && ns < hi, "{ns} ns not in bucket {bucket} [{lo}, {hi})");
        }
        let mut want = vec![0u64; LatencyHistogram::NUM_BUCKETS];
        for &(_, bucket) in &cases {
            want[bucket] += 1;
        }
        assert_eq!(h.buckets, want);
    }

    #[test]
    fn total_cost_sums_energy_and_latency() {
        let mut m = RunMetrics::default();
        m.record(&cost(2.0));
        m.record(&cost(4.0));
        let t = m.total_cost();
        assert!((t.latency - 6e-9).abs() < 1e-18);
        assert!((t.energy.total() - 2e-15).abs() < 1e-25);
    }

    #[test]
    fn prediction_report_errors_and_tolerance() {
        let meas = OpCost { energy: EnergyBreakdown { rbl: 100.0, ..Default::default() }, latency: 10.0 };
        let pred = OpCost { energy: EnergyBreakdown { rbl: 110.0, ..Default::default() }, latency: 9.0 };
        let p = PredictionReport::new(pred, meas);
        assert!((p.energy_error() - 0.1).abs() < 1e-12);
        assert!((p.latency_error() + 0.1).abs() < 1e-12);
        assert!(p.within(0.2));
        assert!(!p.within(0.05));
        let exact = PredictionReport::new(meas, meas);
        assert!(exact.within(0.0));
        assert!(exact.report("x").contains("+0.00%"));
    }
}
