//! Run metrics: counters, latency histogram, and aggregated energy — what
//! the coordinator and the end-to-end examples report.

use crate::energy::{EnergyBreakdown, OpCost};

/// Log-bucketed latency histogram (nanosecond ops up to seconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) nanoseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_ns: f64,
    max_ns: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: vec![0; 40], count: 0, sum_ns: 0.0, max_ns: 0.0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, seconds: f64) {
        let ns = seconds * 1e9;
        let idx = if ns < 1.0 {
            0
        } else {
            (ns.log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns / self.count as f64
        }
    }

    pub fn max_ns(&self) -> f64 {
        self.max_ns
    }

    /// Approximate percentile from the log buckets (upper bound of the
    /// bucket containing the p-th sample).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Aggregated metrics for a stream of operations.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub ops: u64,
    pub errors: u64,
    pub energy: EnergyBreakdown,
    pub model_latency: LatencyHistogram,
    /// Wall-clock time of the run (set by the driver).
    pub wall_seconds: f64,
}

impl RunMetrics {
    pub fn record(&mut self, cost: &OpCost) {
        self.ops += 1;
        self.energy = self.energy.add(&cost.energy);
        self.model_latency.record(cost.latency);
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &RunMetrics) {
        self.ops += other.ops;
        self.errors += other.errors;
        self.energy = self.energy.add(&other.energy);
        self.model_latency.merge(&other.model_latency);
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
    }

    /// Modeled ops/s implied by the summed device latency.
    pub fn modeled_throughput(&self) -> f64 {
        let total_s = self.model_latency.mean_ns() * 1e-9 * self.ops as f64;
        if total_s > 0.0 {
            self.ops as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: {} ops ({} errors), modeled energy {:.3} nJ, \
             mean op latency {:.3} ns, modeled throughput {:.2} Mop/s, \
             wall {:.3} s",
            self.ops,
            self.errors,
            self.energy.total() * 1e9,
            self.model_latency.mean_ns(),
            self.modeled_throughput() / 1e6,
            self.wall_seconds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(ns: f64) -> OpCost {
        OpCost {
            energy: EnergyBreakdown { rbl: 1e-15, ..Default::default() },
            latency: ns * 1e-9,
        }
    }

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = LatencyHistogram::default();
        for ns in [1.0, 2.0, 4.0, 8.0] {
            h.record(ns * 1e-9);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ns() - 3.75).abs() < 1e-9);
        assert_eq!(h.max_ns(), 8.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-9);
        }
        assert!(h.percentile_ns(50.0) <= h.percentile_ns(99.0));
        assert!(h.percentile_ns(99.0) >= 512.0);
    }

    #[test]
    fn metrics_accumulate_and_merge() {
        let mut m1 = RunMetrics::default();
        m1.record(&cost(2.0));
        m1.record(&cost(4.0));
        let mut m2 = RunMetrics::default();
        m2.record(&cost(8.0));
        m2.record_error();
        m1.merge(&m2);
        assert_eq!(m1.ops, 3);
        assert_eq!(m1.errors, 1);
        assert!((m1.energy.total() - 3e-15).abs() < 1e-25);
    }

    #[test]
    fn report_is_informative() {
        let mut m = RunMetrics::default();
        m.record(&cost(3.0));
        let r = m.report("test");
        assert!(r.contains("1 ops"));
        assert!(r.contains("test"));
    }
}
