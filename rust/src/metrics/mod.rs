//! Run metrics: counters, latency histogram, and aggregated energy — what
//! the coordinator and the end-to-end examples report.

use crate::energy::{EnergyBreakdown, OpCost};

/// Log-bucketed latency histogram (nanosecond ops up to seconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket 0 covers [0, 2) ns (every sub-nanosecond sample lands there
    /// together with the [1, 2) ns ones); bucket i >= 1 covers
    /// [2^i, 2^(i+1)) ns; the last bucket absorbs everything above its
    /// lower edge.  See `bucket_bounds`.
    buckets: Vec<u64>,
    count: u64,
    sum_ns: f64,
    max_ns: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: vec![0; LatencyHistogram::NUM_BUCKETS],
            count: 0,
            sum_ns: 0.0,
            max_ns: 0.0,
        }
    }
}

impl LatencyHistogram {
    /// Number of buckets (fixed; the last one is open-ended).
    pub const NUM_BUCKETS: usize = 40;

    /// The [lo, hi) nanosecond range bucket `i` covers.  Bucket 0 is
    /// [0, 2) — NOT [2^0, 2^1) — because `record` floors sub-nanosecond
    /// samples into the first bucket; the last bucket's upper edge is
    /// reported as infinity since it absorbs all larger samples.
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        assert!(i < Self::NUM_BUCKETS, "bucket {i} out of range");
        let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
        let hi = if i + 1 == Self::NUM_BUCKETS {
            f64::INFINITY
        } else {
            (1u64 << (i + 1)) as f64
        };
        (lo, hi)
    }

    pub fn record(&mut self, seconds: f64) {
        let ns = seconds * 1e9;
        let idx = if ns < 1.0 {
            0
        } else {
            (ns.log2().floor() as usize).min(self.buckets.len() - 1)
        };
        // saturating: a soak run that fills a counter clamps at the cap
        // instead of panicking in debug builds (overflow hygiene, see
        // the u64::MAX-vicinity test)
        self.buckets[idx] = self.buckets[idx].saturating_add(1);
        self.count = self.count.saturating_add(1);
        self.sum_ns += ns;
        if ns > self.max_ns {
            self.max_ns = ns;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns / self.count as f64
        }
    }

    /// Exact sum of all recorded samples (ns) — unlike the percentiles,
    /// this is not bucket-quantized.
    pub fn sum_ns(&self) -> f64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> f64 {
        self.max_ns
    }

    /// Approximate percentile from the log buckets (upper bound of the
    /// bucket containing the p-th sample).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max_ns
    }

    /// Fold another histogram into this one (buckets, count, sum, max) —
    /// how per-shard / per-tenant histograms aggregate into registry
    /// snapshots.  Merging is exactly equivalent to having recorded both
    /// sample streams into one histogram (pinned by test).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Per-bucket counts, index-aligned with [`Self::bucket_bounds`] —
    /// what `observe::Histogram::set_to_snapshot` ratchets against.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// Aggregated metrics for a stream of operations.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub ops: u64,
    pub errors: u64,
    pub energy: EnergyBreakdown,
    pub model_latency: LatencyHistogram,
    /// Wall-clock time of the run (set by the driver).
    pub wall_seconds: f64,
    /// Array access counters snapshotted from the engine(s) at collection
    /// time (`Engine::array_stats`) — includes the per-tier activation
    /// split of the tiered activation kernel.
    pub array: crate::array::ArrayStats,
}

impl RunMetrics {
    pub fn record(&mut self, cost: &OpCost) {
        self.ops += 1;
        self.energy = self.energy.add(&cost.energy);
        self.model_latency.record(cost.latency);
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &RunMetrics) {
        self.ops += other.ops;
        self.errors += other.errors;
        self.energy = self.energy.add(&other.energy);
        self.model_latency.merge(&other.model_latency);
        self.wall_seconds = self.wall_seconds.max(other.wall_seconds);
        self.array = self.array.merged(&other.array);
    }

    /// Modeled ops/s implied by the summed device latency.
    pub fn modeled_throughput(&self) -> f64 {
        let total_s = self.model_latency.mean_ns() * 1e-9 * self.ops as f64;
        if total_s > 0.0 {
            self.ops as f64 / total_s
        } else {
            0.0
        }
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: {} ops ({} errors), modeled energy {:.3} nJ, \
             mean op latency {:.3} ns, p50/p95/p99 {:.0}/{:.0}/{:.0} ns, \
             modeled throughput {:.2} Mop/s, \
             activations {} ({} digital, {} masked, det cols {:.1}%), \
             wall {:.3} s",
            self.ops,
            self.errors,
            self.energy.total() * 1e9,
            self.model_latency.mean_ns(),
            self.model_latency.percentile_ns(50.0),
            self.model_latency.percentile_ns(95.0),
            self.model_latency.percentile_ns(99.0),
            self.modeled_throughput() / 1e6,
            self.array.dual_activations,
            self.array.digital_activations,
            self.array.masked_activations,
            self.array.det_col_fraction() * 100.0,
            self.wall_seconds,
        )
    }

    /// The total modeled cost this run accumulated (energy summed, latency
    /// summed serially) — what the planner's predictions are checked
    /// against.
    pub fn total_cost(&self) -> OpCost {
        OpCost {
            energy: self.energy,
            latency: self.model_latency.sum_ns() * 1e-9,
        }
    }

    /// Publish this (cumulative) snapshot into a metric registry:
    /// run counters, the modeled-latency histogram, and the kernel-tier
    /// `ArrayStats` split (per-tier activation counters, det-fraction
    /// gauge, xval counters).  Counters ratchet (`set_at_least`) so
    /// re-publishing a newer snapshot of the same source is idempotent;
    /// `labels` must identify the source (e.g. `queue="0"`) so distinct
    /// coordinators don't collapse into one series.
    pub fn publish(&self, reg: &crate::observe::Registry, labels: &[(&str, &str)]) {
        reg.counter("adra.run.ops", "Operations executed (engine-charged).", labels)
            .set_at_least(self.ops);
        reg.counter("adra.run.errors", "Operations that returned an engine error.", labels)
            .set_at_least(self.errors);
        reg.gauge("adra.run.energy_nj", "Cumulative modeled energy (nJ).", labels)
            .set(self.energy.total() * 1e9);
        reg.histogram("adra.run.op_latency_ns", "Modeled per-op device latency (ns).", labels)
            .set_to_snapshot(&self.model_latency);

        let a = &self.array;
        reg.counter("adra.array.writes", "Array word writes.", labels).set_at_least(a.writes);
        reg.counter("adra.array.reads", "Array single-row reads.", labels).set_at_least(a.reads);
        reg.counter(
            "adra.array.half_selected_cols",
            "Column accesses on half-selected words (scheme-1 pseudo-CiM columns).",
            labels,
        )
        .set_at_least(a.half_selected_cols);
        let with_tier = |tier: &'static str| -> Vec<(&str, &str)> {
            let mut l = labels.to_vec();
            l.push(("tier", tier));
            l
        };
        const ACT_HELP: &str =
            "Dual-row activations by serving tier (digital = packed plane, masked = \
             packed majority + analog minority, analog = full analog pipeline).";
        reg.counter("adra.array.activations", ACT_HELP, &with_tier("digital"))
            .set_at_least(a.digital_activations);
        reg.counter("adra.array.activations", ACT_HELP, &with_tier("masked"))
            .set_at_least(a.masked_activations);
        reg.counter("adra.array.activations", ACT_HELP, &with_tier("analog")).set_at_least(
            a.dual_activations
                .saturating_sub(a.digital_activations)
                .saturating_sub(a.masked_activations),
        );
        reg.counter("adra.array.det_cols", "Columns served from the packed planes.", labels)
            .set_at_least(a.det_cols);
        reg.counter(
            "adra.array.marginal_cols",
            "Packed-path columns routed through the analog pipeline by the margin mask.",
            labels,
        )
        .set_at_least(a.marginal_cols);
        reg.gauge(
            "adra.array.det_fraction",
            "Fraction of packed-path columns served deterministically.",
            labels,
        )
        .set(a.det_col_fraction());
        reg.counter("adra.array.xval_checks", "Sampled digital-vs-analog cross-validation checks.", labels)
            .set_at_least(a.xval_checks);
        reg.counter(
            "adra.array.xval_mismatches",
            "Cross-validation divergences (must stay 0 on a calibrated configuration).",
            labels,
        )
        .set_at_least(a.xval_mismatches);
    }
}

/// Predicted-vs-measured cost comparison: the planner predicts a program's
/// cost from its tables at lowering time; execution measures it through
/// the engines' per-op accounting.  Relative errors are signed
/// (positive = over-prediction).
#[derive(Clone, Copy, Debug)]
pub struct PredictionReport {
    pub predicted: OpCost,
    pub measured: OpCost,
}

impl PredictionReport {
    pub fn new(predicted: OpCost, measured: OpCost) -> Self {
        Self { predicted, measured }
    }

    fn rel(predicted: f64, measured: f64) -> f64 {
        if measured == 0.0 {
            if predicted == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (predicted - measured) / measured
        }
    }

    /// (predicted - measured) / measured on total energy.
    pub fn energy_error(&self) -> f64 {
        Self::rel(self.predicted.energy.total(), self.measured.energy.total())
    }

    /// (predicted - measured) / measured on summed latency.
    pub fn latency_error(&self) -> f64 {
        Self::rel(self.predicted.latency, self.measured.latency)
    }

    /// Are both errors within +-tol (e.g. 0.2 for 20%)?
    pub fn within(&self, tol: f64) -> bool {
        self.energy_error().abs() <= tol && self.latency_error().abs() <= tol
    }

    /// Publish this comparison into a registry: signed relative errors as
    /// gauges (latest observation) and |error| histograms in ppm
    /// (distribution over runs), labeled by op class — the persisted
    /// calibration signal the adaptive cost model (ROADMAP open item 1)
    /// consumes.
    pub fn publish(&self, reg: &crate::observe::Registry, op_class: &str) {
        const GAUGE_HELP: &str =
            "Signed relative predicted-vs-measured cost error of the last run \
             ((predicted - measured) / measured).";
        const HIST_HELP: &str =
            "Absolute predicted-vs-measured relative cost error per run, in ppm.";
        for (kind, err) in
            [("energy", self.energy_error()), ("latency", self.latency_error())]
        {
            let labels = [("kind", kind), ("op_class", op_class)];
            reg.gauge("adra.planner.prediction_error", GAUGE_HELP, &labels).set(err);
            reg.histogram("adra.planner.prediction_error_ppm", HIST_HELP, &labels)
                .record(err.abs() * 1e6);
        }
    }

    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: predicted {:.3} nJ / {:.1} ns vs measured {:.3} nJ / {:.1} ns \
             (energy err {:+.2}%, latency err {:+.2}%)",
            self.predicted.energy.total() * 1e9,
            self.predicted.latency * 1e9,
            self.measured.energy.total() * 1e9,
            self.measured.latency * 1e9,
            self.energy_error() * 100.0,
            self.latency_error() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(ns: f64) -> OpCost {
        OpCost {
            energy: EnergyBreakdown { rbl: 1e-15, ..Default::default() },
            latency: ns * 1e-9,
        }
    }

    #[test]
    fn histogram_counts_and_mean() {
        let mut h = LatencyHistogram::default();
        for ns in [1.0, 2.0, 4.0, 8.0] {
            h.record(ns * 1e-9);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ns() - 3.75).abs() < 1e-9);
        assert_eq!(h.max_ns(), 8.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-9);
        }
        assert!(h.percentile_ns(50.0) <= h.percentile_ns(99.0));
        assert!(h.percentile_ns(99.0) >= 512.0);
    }

    #[test]
    fn metrics_accumulate_and_merge() {
        let mut m1 = RunMetrics::default();
        m1.record(&cost(2.0));
        m1.record(&cost(4.0));
        let mut m2 = RunMetrics::default();
        m2.record(&cost(8.0));
        m2.record_error();
        m1.merge(&m2);
        assert_eq!(m1.ops, 3);
        assert_eq!(m1.errors, 1);
        assert!((m1.energy.total() - 3e-15).abs() < 1e-25);
    }

    #[test]
    fn report_is_informative() {
        let mut m = RunMetrics::default();
        m.record(&cost(3.0));
        let r = m.report("test");
        assert!(r.contains("1 ops"));
        assert!(r.contains("test"));
        // tail-latency line: one 3 ns sample lands in bucket [2, 4), so
        // every percentile reports the 4 ns bucket upper bound
        assert!(r.contains("p50/p95/p99 4/4/4 ns"), "{r}");
    }

    /// Pin the bucket edges: bucket 0 is [0, 2) ns (doc/code mismatch fix
    /// — `record` floors log2, so 1.0 ns and 1.9 ns BOTH land in bucket 0
    /// alongside sub-ns samples), bucket i >= 1 is [2^i, 2^(i+1)), and the
    /// last bucket clamps.
    #[test]
    fn bucket_edges_pinned() {
        assert_eq!(LatencyHistogram::bucket_bounds(0), (0.0, 2.0));
        assert_eq!(LatencyHistogram::bucket_bounds(1), (2.0, 4.0));
        assert_eq!(LatencyHistogram::bucket_bounds(5), (32.0, 64.0));
        let (lo, hi) = LatencyHistogram::bucket_bounds(LatencyHistogram::NUM_BUCKETS - 1);
        assert_eq!(lo, (1u64 << 39) as f64);
        assert!(hi.is_infinite());

        let mut h = LatencyHistogram::default();
        // (sample ns, expected bucket): edges exercised on both sides
        let cases = [
            (0.25, 0usize),
            (1.0, 0),
            (1.99, 0),
            (2.0, 1),
            (3.99, 1),
            (4.0, 2),
            (32.0, 5),
            (63.9, 5),
            (1e12, LatencyHistogram::NUM_BUCKETS - 1), // 2^39.9 ns: clamped
        ];
        for &(ns, bucket) in &cases {
            h.record(ns * 1e-9);
            let (lo, hi) = LatencyHistogram::bucket_bounds(bucket);
            assert!(ns >= lo && ns < hi, "{ns} ns not in bucket {bucket} [{lo}, {hi})");
        }
        let mut want = vec![0u64; LatencyHistogram::NUM_BUCKETS];
        for &(_, bucket) in &cases {
            want[bucket] += 1;
        }
        assert_eq!(h.buckets, want);
    }

    /// Pin the merge contract: merging per-shard histograms must be
    /// EXACTLY equivalent to recording every sample into one histogram —
    /// same buckets, count, sum, max, and therefore identical
    /// percentiles at every probed p.
    #[test]
    fn merge_matches_single_histogram_recording() {
        let mut rng_state = 0x9e3779b97f4a7c15u64;
        let mut next = || {
            rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((rng_state >> 33) % 100_000) as f64 * 1e-9 // 0 .. 100 us
        };
        let mut shard_a = LatencyHistogram::default();
        let mut shard_b = LatencyHistogram::default();
        let mut shard_c = LatencyHistogram::default();
        let mut single = LatencyHistogram::default();
        for i in 0..3000 {
            let s = next();
            [&mut shard_a, &mut shard_b, &mut shard_c][i % 3].record(s);
            single.record(s);
        }
        let mut merged = LatencyHistogram::default();
        merged.merge(&shard_a);
        merged.merge(&shard_b);
        merged.merge(&shard_c);
        assert_eq!(merged.buckets(), single.buckets());
        assert_eq!(merged.count(), single.count());
        assert!((merged.sum_ns() - single.sum_ns()).abs() < 1e-6 * single.sum_ns());
        assert_eq!(merged.max_ns(), single.max_ns());
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            assert_eq!(
                merged.percentile_ns(p),
                single.percentile_ns(p),
                "p{p} diverged between merged and single-histogram recording"
            );
        }
    }

    /// Overflow hygiene: counters at the u64::MAX vicinity clamp instead
    /// of panicking in debug builds (long soak runs).
    #[test]
    fn record_and_merge_saturate_at_u64_max() {
        let mut h = LatencyHistogram::default();
        h.count = u64::MAX - 1;
        h.buckets[0] = u64::MAX;
        h.record(0.5e-9); // bucket 0 already full: clamps, count advances
        assert_eq!(h.count, u64::MAX);
        assert_eq!(h.buckets[0], u64::MAX);
        h.record(0.5e-9); // count now full too: no panic, stays clamped
        assert_eq!(h.count, u64::MAX);

        let mut other = LatencyHistogram::default();
        other.record(3e-9);
        h.merge(&other);
        assert_eq!(h.count, u64::MAX, "merge saturates");
        assert_eq!(h.buckets[1], 1);
    }

    #[test]
    fn run_metrics_publish_exposes_tier_split() {
        let reg = crate::observe::Registry::new();
        let mut m = RunMetrics::default();
        m.record(&cost(2.0));
        m.array.dual_activations = 10;
        m.array.digital_activations = 6;
        m.array.masked_activations = 3;
        m.array.det_cols = 99;
        m.array.marginal_cols = 1;
        m.publish(&reg, &[("queue", "7")]);
        let text = crate::observe::expose_text(&reg);
        assert!(text.contains("adra_run_ops{queue=\"7\"} 1"), "{text}");
        assert!(text.contains("adra_array_activations{queue=\"7\",tier=\"digital\"} 6"), "{text}");
        assert!(text.contains("adra_array_activations{queue=\"7\",tier=\"masked\"} 3"), "{text}");
        assert!(text.contains("adra_array_activations{queue=\"7\",tier=\"analog\"} 1"), "{text}");
        assert!(text.contains("adra_array_det_fraction{queue=\"7\"} 0.99"), "{text}");
        assert!(text.contains("adra_run_op_latency_ns_count{queue=\"7\"} 1"), "{text}");
        // re-publishing the same snapshot is idempotent
        m.publish(&reg, &[("queue", "7")]);
        assert!(crate::observe::expose_text(&reg).contains("adra_run_ops{queue=\"7\"} 1"));
    }

    #[test]
    fn prediction_report_publishes_per_class() {
        let reg = crate::observe::Registry::new();
        let meas = OpCost { energy: EnergyBreakdown { rbl: 100.0, ..Default::default() }, latency: 10.0 };
        let pred = OpCost { energy: EnergyBreakdown { rbl: 110.0, ..Default::default() }, latency: 9.0 };
        PredictionReport::new(pred, meas).publish(&reg, "dual");
        let text = crate::observe::expose_text(&reg);
        assert!(
            text.contains("adra_planner_prediction_error{kind=\"energy\",op_class=\"dual\"} 0.1"),
            "{text}"
        );
        assert!(
            text.contains("adra_planner_prediction_error{kind=\"latency\",op_class=\"dual\"} -0.1"),
            "{text}"
        );
        assert!(text.contains("adra_planner_prediction_error_ppm_count"), "{text}");
    }

    #[test]
    fn total_cost_sums_energy_and_latency() {
        let mut m = RunMetrics::default();
        m.record(&cost(2.0));
        m.record(&cost(4.0));
        let t = m.total_cost();
        assert!((t.latency - 6e-9).abs() < 1e-18);
        assert!((t.energy.total() - 2e-15).abs() < 1e-25);
    }

    #[test]
    fn prediction_report_errors_and_tolerance() {
        let meas = OpCost { energy: EnergyBreakdown { rbl: 100.0, ..Default::default() }, latency: 10.0 };
        let pred = OpCost { energy: EnergyBreakdown { rbl: 110.0, ..Default::default() }, latency: 9.0 };
        let p = PredictionReport::new(pred, meas);
        assert!((p.energy_error() - 0.1).abs() < 1e-12);
        assert!((p.latency_error() + 0.1).abs() < 1e-12);
        assert!(p.within(0.2));
        assert!(!p.within(0.05));
        let exact = PredictionReport::new(meas, meas);
        assert!(exact.within(0.0));
        assert!(exact.report("x").contains("+0.00%"));
    }
}
