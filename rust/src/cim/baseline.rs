//! The near-memory baseline engine (the paper's comparison point).
//!
//! Commutative Boolean functions use prior-work symmetric dual-row CiM
//! (Fig. 1) — those were already single-access before ADRA.  Everything
//! that needs A and B *separately* (read2, subtraction, comparison,
//! non-commutative Booleans) requires **two full reads** followed by
//! near-memory compute, because the symmetric activation maps (0,1) and
//! (1,0) to the same senseline current.
//!
//! `try_single_access_sub` demonstrates the mapping problem explicitly:
//! it attempts the subtraction from one symmetric access and returns the
//! ambiguity error — this is the paper's Section II.A argument as code.

use crate::array::FefetArray;
use crate::config::SimConfig;
use crate::energy::EnergyModel;
use crate::logic::{and_tree_equal, ripple_add_sub, sense_from_bits, CompareResult};
use crate::sensing::{CurrentRefs, CurrentSenseBank};

use super::ops::{BoolFn, CimOp, CimResult, CimValue, Engine, EngineError};

/// Prior-work near-memory engine over the same array substrate.
pub struct BaselineEngine {
    cfg: SimConfig,
    array: FefetArray,
    energy: EnergyModel,
    bank: CurrentSenseBank,
    /// Symmetric-activation references (both rows at V_GREAD2): only
    /// three distinguishable levels.
    sym_refs: CurrentRefs,
}

impl BaselineEngine {
    pub fn new(cfg: &SimConfig) -> Self {
        let p = &cfg.device;
        Self {
            cfg: cfg.clone(),
            array: FefetArray::new(cfg),
            energy: EnergyModel::new(cfg),
            bank: CurrentSenseBank::new(CurrentRefs::derive(p, p.v_gread1, p.v_gread2)),
            sym_refs: CurrentRefs::derive(p, p.v_gread2, p.v_gread2),
        }
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn array(&self) -> &FefetArray {
        &self.array
    }

    pub fn array_mut(&mut self) -> &mut FefetArray {
        &mut self.array
    }

    fn check_word(&self, row: usize, word: usize) -> Result<(), EngineError> {
        if row >= self.cfg.rows || word >= self.cfg.words_per_row() {
            return Err(EngineError::OutOfRange(format!("row {row} word {word}")));
        }
        Ok(())
    }

    fn word_cols(&self, word: usize) -> (usize, usize) {
        let lo = word * self.cfg.word_bits;
        (lo, lo + self.cfg.word_bits)
    }

    /// One full read through the sensing path.
    fn read_word(&mut self, row: usize, word: usize) -> Result<u64, EngineError> {
        self.check_word(row, word)?;
        let vg = self.cfg.device.v_gread2;
        let (lo, hi) = self.word_cols(word);
        let currents = self.array.read_currents(row, lo, hi, vg);
        let mut v = 0u64;
        for (i, &c) in currents.iter().enumerate() {
            if self.bank.sense_read(c) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    fn mask(&self) -> u64 {
        if self.cfg.word_bits == 64 {
            u64::MAX
        } else {
            (1u64 << self.cfg.word_bits) - 1
        }
    }

    /// Symmetric dual-row activation (prior-work CiM): per-column OR and
    /// AND decisions — the only information three levels can carry.
    fn symmetric_or_and(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Vec<(bool, bool)>, EngineError> {
        let vg = self.cfg.device.v_gread2;
        let (lo, hi) = self.word_cols(word);
        let isl = self.array.dual_row_currents(row_a, row_b, lo, hi, vg, vg);
        Ok(isl
            .iter()
            .map(|&i| (i > self.sym_refs.i_ref_or, i > self.sym_refs.i_ref_and))
            .collect())
    }

    /// The Section II.A demonstration: a symmetric single access cannot
    /// produce A-B because (0,1) and (1,0) are indistinguishable.  Returns
    /// `EngineError::Unsupported` whenever any column senses the ambiguous
    /// middle level (OR=1, AND=0), and the correct difference only in the
    /// lucky data-dependent cases where no column is ambiguous.
    pub fn try_single_access_sub(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<i128, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        let or_and = self.symmetric_or_and(row_a, row_b, word)?;
        if or_and.iter().any(|&(or, and)| or && !and) {
            return Err(EngineError::Unsupported(
                "symmetric activation: (0,1) and (1,0) map to the same \
                 I_SL — cannot form A-B in one access"
                    .into(),
            ));
        }
        // unambiguous columns are (0,0) or (1,1): A == B, difference 0
        Ok(0)
    }

    /// Two reads + near-memory digital compute (the paper's baseline).
    fn two_read_compute<F: FnOnce(u64, u64) -> CimValue>(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
        f: F,
    ) -> Result<CimResult, EngineError> {
        let a = self.read_word(row_a, word)?;
        let b = self.read_word(row_b, word)?;
        Ok(CimResult { value: f(a, b), cost: self.energy.baseline_cost() })
    }
}

impl Engine for BaselineEngine {
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError> {
        let nbits = self.cfg.word_bits;
        match *op {
            CimOp::Write { addr, value } => {
                self.check_word(addr.row, addr.word)?;
                self.array.write_word(addr.row, addr.word, value);
                Ok(CimResult { value: CimValue::None, cost: self.energy.write_cost() })
            }
            CimOp::Read(addr) => {
                let v = self.read_word(addr.row, addr.word)?;
                Ok(CimResult { value: CimValue::Word(v), cost: self.energy.read_cost() })
            }
            // two separate words need two accesses on the baseline
            CimOp::Read2 { row_a, row_b, word } => {
                self.two_read_compute(row_a, row_b, word, |a, b| CimValue::Pair(a, b))
            }
            CimOp::Bool { f, row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                if f.commutative() {
                    // prior-work single-access CiM: symmetric activation
                    let or_and = self.symmetric_or_and(row_a, row_b, word)?;
                    let mut v = 0u64;
                    for (i, &(or, and)) in or_and.iter().enumerate() {
                        let bit = match f {
                            BoolFn::And => and,
                            BoolFn::Or => or,
                            BoolFn::Nand => !and,
                            BoolFn::Nor => !or,
                            BoolFn::Xor => or && !and,
                            BoolFn::Xnor => !(or && !and),
                            _ => unreachable!("non-commutative handled below"),
                        };
                        if bit {
                            v |= 1 << i;
                        }
                    }
                    Ok(CimResult { value: CimValue::Word(v), cost: self.energy.cim_cost() })
                } else {
                    let mask = self.mask();
                    self.two_read_compute(row_a, row_b, word, |a, b| {
                        CimValue::Word(f.apply(a, b, mask))
                    })
                }
            }
            CimOp::Add { row_a, row_b, word } => {
                // commutative: prior-work CiM adds from OR/AND in one access
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let or_and = self.symmetric_or_and(row_a, row_b, word)?;
                let sense: Vec<_> = or_and
                    .iter()
                    .map(|&(or, and)| crate::sensing::SenseOut { or, and, b: false })
                    .collect();
                let r = ripple_add_sub(&sense, false);
                Ok(CimResult {
                    value: CimValue::Sum(r.as_unsigned()),
                    cost: self.energy.cim_cost(),
                })
            }
            CimOp::Sub { row_a, row_b, word } => {
                // non-commutative: two reads + near-memory subtract
                self.two_read_compute(row_a, row_b, word, |a, b| {
                    let r = ripple_add_sub(&sense_from_bits(a, b, nbits), true);
                    CimValue::Diff(r.as_signed())
                })
            }
            CimOp::Compare { row_a, row_b, word } => {
                self.two_read_compute(row_a, row_b, word, |a, b| {
                    let r = ripple_add_sub(&sense_from_bits(a, b, nbits), true);
                    let res = if and_tree_equal(&r.bits) {
                        CompareResult::Equal
                    } else if r.sign() {
                        CompareResult::Less
                    } else {
                        CompareResult::Greater
                    };
                    CimValue::Ordering(res)
                })
            }
        }
    }

    fn array_stats(&self) -> Option<crate::array::ArrayStats> {
        Some(self.array.stats())
    }

    fn name(&self) -> &'static str {
        "baseline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::WordAddr;
    use crate::config::SensingScheme;
    use crate::util::rng::Rng;

    fn engine() -> BaselineEngine {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        BaselineEngine::new(&cfg)
    }

    fn setup(e: &mut BaselineEngine, a: u64, b: u64) {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    }

    #[test]
    fn subtraction_needs_two_reads() {
        let mut e = engine();
        setup(&mut e, 44, 17);
        e.array_mut().reset_stats();
        let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(27));
        let s = e.array().stats();
        assert_eq!(s.reads, 2, "baseline subtraction must take TWO reads");
        assert_eq!(s.dual_activations, 0);
    }

    #[test]
    fn commutative_bool_single_access() {
        let mut e = engine();
        setup(&mut e, 0b1100, 0b1010);
        e.array_mut().reset_stats();
        let r = e
            .execute(&CimOp::Bool { f: BoolFn::Xor, row_a: 0, row_b: 1, word: 0 })
            .unwrap();
        assert_eq!(r.value, CimValue::Word(0b0110));
        assert_eq!(e.array().stats().dual_activations, 1);
        assert_eq!(e.array().stats().reads, 0);
    }

    #[test]
    fn add_is_single_access_prior_work() {
        let mut e = engine();
        let mut rng = Rng::new(5);
        for _ in 0..16 {
            let (a, b) = (rng.below(256), rng.below(256));
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Sum((a + b) as u128), "a={a} b={b}");
        }
    }

    #[test]
    fn many_to_one_mapping_blocks_single_access_sub() {
        let mut e = engine();
        setup(&mut e, 0b0001, 0b0010); // columns 0,1 hit the ambiguous level
        let err = e.try_single_access_sub(0, 1, 0).unwrap_err();
        assert!(matches!(err, EngineError::Unsupported(_)), "{err}");
        // equal words have no ambiguous column -> trivially 0
        setup(&mut e, 0b1111, 0b1111);
        assert_eq!(e.try_single_access_sub(0, 1, 0).unwrap(), 0);
    }

    #[test]
    fn sub_and_compare_values_match_integers() {
        let mut e = engine();
        let mut rng = Rng::new(7);
        for _ in 0..16 {
            let (a, b) = (rng.below(256), rng.below(256));
            setup(&mut e, a, b);
            let sub = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
            let sa = (a as i128) - if a >= 128 { 256 } else { 0 };
            let sb = (b as i128) - if b >= 128 { 256 } else { 0 };
            assert_eq!(sub.value, CimValue::Diff(sa - sb));
        }
    }

    #[test]
    fn baseline_sub_cost_exceeds_cim_cost() {
        let mut e = engine();
        setup(&mut e, 9, 4);
        let sub = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let add = e.execute(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert!(sub.cost.energy.total() > add.cost.energy.total());
        assert!(sub.cost.latency > add.cost.latency);
    }
}
