//! In-memory aggregate queries built from ADRA primitives: the database
//! operations (the paper's motivating workload) that compose comparison
//! and subtraction — range filters, min/max scans, top-k selection, and
//! delta (difference) encoding.  Each reports its total modeled cost and
//! the number of array activations, so examples/benches can quantify the
//! ADRA advantage at query level rather than op level.

use crate::cim::adra::AdraEngine;
use crate::cim::ops::{CimOp, CimValue, Engine, EngineError, WordAddr};
use crate::energy::OpCost;
use crate::logic::CompareResult;

/// Aggregate query results.
#[derive(Clone, Debug)]
pub struct QueryReport<T> {
    pub result: T,
    pub cost: OpCost,
    pub activations: u64,
}

/// Aggregate-query layer over one engine.
pub struct AggregateEngine<'a> {
    engine: &'a mut AdraEngine,
}

impl<'a> AggregateEngine<'a> {
    pub fn new(engine: &'a mut AdraEngine) -> Self {
        Self { engine }
    }

    fn compare(
        &mut self,
        lhs: WordAddr,
        rhs_row: usize,
        cost: &mut OpCost,
    ) -> Result<CompareResult, EngineError> {
        let r = self.engine.execute(&CimOp::Compare {
            row_a: lhs.row,
            row_b: rhs_row,
            word: lhs.word,
        })?;
        *cost = cost.then(&r.cost);
        match r.value {
            CimValue::Ordering(o) => Ok(o),
            _ => unreachable!(),
        }
    }

    /// Range filter: indices of records with lo <= value < hi.
    /// `lo_row` / `hi_row` hold the bounds broadcast across every word.
    pub fn range_filter(
        &mut self,
        records: &[WordAddr],
        lo_row: usize,
        hi_row: usize,
    ) -> Result<QueryReport<Vec<usize>>, EngineError> {
        let before = self.engine.array().stats().dual_activations;
        let mut cost = OpCost::default();
        let mut hits = Vec::new();
        for (i, addr) in records.iter().enumerate() {
            // value >= lo  <=>  NOT (value < lo)
            let ge_lo = self.compare(*addr, lo_row, &mut cost)? != CompareResult::Less;
            if !ge_lo {
                continue;
            }
            let lt_hi = self.compare(*addr, hi_row, &mut cost)? == CompareResult::Less;
            if lt_hi {
                hits.push(i);
            }
        }
        Ok(QueryReport {
            result: hits,
            cost,
            activations: self.engine.array().stats().dual_activations - before,
        })
    }

    /// Minimum scan: index of the smallest record (two's-complement).
    pub fn min_scan(
        &mut self,
        records: &[WordAddr],
    ) -> Result<QueryReport<usize>, EngineError> {
        assert!(!records.is_empty());
        let before = self.engine.array().stats().dual_activations;
        let mut cost = OpCost::default();
        let mut best = 0usize;
        for i in 1..records.len() {
            // compare record[i] against current best: both are in-memory
            // words, so this is a plain dual-row compare when word indices
            // match, else via the subtraction path on the wider window
            let (a, b) = (records[i], records[best]);
            if a.word == b.word && a.row != b.row {
                let r = self.engine.execute(&CimOp::Compare {
                    row_a: a.row,
                    row_b: b.row,
                    word: a.word,
                })?;
                cost = cost.then(&r.cost);
                if r.value == CimValue::Ordering(CompareResult::Less) {
                    best = i;
                }
            } else {
                // different columns: read both (2 accesses, like baseline)
                let ra = self.engine.execute(&CimOp::Read(a))?;
                let rb = self.engine.execute(&CimOp::Read(b))?;
                cost = cost.then(&ra.cost).then(&rb.cost);
                if (ra.value.word().unwrap() as i64) < (rb.value.word().unwrap() as i64) {
                    best = i;
                }
            }
        }
        Ok(QueryReport {
            result: best,
            cost,
            activations: self.engine.array().stats().dual_activations - before,
        })
    }

    /// Delta encoding: in-memory differences value[i] - value[i-1] for a
    /// column of records stored in consecutive rows at the same word.
    pub fn delta_encode(
        &mut self,
        rows: &[usize],
        word: usize,
    ) -> Result<QueryReport<Vec<i128>>, EngineError> {
        assert!(rows.len() >= 2);
        let before = self.engine.array().stats().dual_activations;
        let mut cost = OpCost::default();
        let mut deltas = Vec::with_capacity(rows.len() - 1);
        for w in rows.windows(2) {
            let r = self.engine.execute(&CimOp::Sub { row_a: w[1], row_b: w[0], word })?;
            cost = cost.then(&r.cost);
            deltas.push(r.value.diff().unwrap());
        }
        Ok(QueryReport {
            result: deltas,
            cost,
            activations: self.engine.array().stats().dual_activations - before,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::util::rng::Rng;

    fn setup(values: &[u64]) -> (SimConfig, AdraEngine, Vec<WordAddr>) {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        let mut e = AdraEngine::new(&cfg);
        let mut addrs = Vec::new();
        for (i, &v) in values.iter().enumerate() {
            let addr = WordAddr { row: i, word: 0 };
            e.execute(&CimOp::Write { addr, value: v }).unwrap();
            addrs.push(addr);
        }
        (cfg, e, addrs)
    }

    #[test]
    fn range_filter_matches_ground_truth() {
        let vals = [5u64, 120, 44, 99, 13, 77, 61, 2];
        let (_, mut e, addrs) = setup(&vals);
        // bounds rows: lo = 10, hi = 80 (values kept in signed-positive range)
        e.execute(&CimOp::Write { addr: WordAddr { row: 20, word: 0 }, value: 10 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 21, word: 0 }, value: 80 }).unwrap();
        let mut agg = AggregateEngine::new(&mut e);
        let rep = agg.range_filter(&addrs, 20, 21).unwrap();
        let want: Vec<usize> = vals
            .iter()
            .enumerate()
            .filter(|(_, &v)| (10..80).contains(&v))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(rep.result, want);
        assert!(rep.cost.energy.total() > 0.0);
        assert!(rep.activations >= want.len() as u64);
    }

    #[test]
    fn min_scan_finds_minimum() {
        let vals = [55u64, 13, 99, 4, 86, 4, 120];
        let (_, mut e, addrs) = setup(&vals);
        let mut agg = AggregateEngine::new(&mut e);
        let rep = agg.min_scan(&addrs).unwrap();
        assert_eq!(vals[rep.result], 4);
        // n-1 compares, all same-word -> all single activations
        assert_eq!(rep.activations, (vals.len() - 1) as u64);
    }

    #[test]
    fn delta_encode_matches_differences() {
        let vals = [10u64, 25, 7, 7, 100];
        let (_, mut e, _) = setup(&vals);
        let rows: Vec<usize> = (0..vals.len()).collect();
        let mut agg = AggregateEngine::new(&mut e);
        let rep = agg.delta_encode(&rows, 0).unwrap();
        let want: Vec<i128> = vals.windows(2).map(|w| w[1] as i128 - w[0] as i128).collect();
        assert_eq!(rep.result, want);
        assert_eq!(rep.activations, (vals.len() - 1) as u64);
    }

    #[test]
    fn randomized_range_filters() {
        let mut rng = Rng::new(33);
        for round in 0..5 {
            let vals: Vec<u64> = (0..16).map(|_| rng.below(120)).collect();
            let (_, mut e, addrs) = setup(&vals);
            e.execute(&CimOp::Write { addr: WordAddr { row: 30, word: 0 }, value: 30 }).unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 31, word: 0 }, value: 90 }).unwrap();
            let mut agg = AggregateEngine::new(&mut e);
            let rep = agg.range_filter(&addrs, 30, 31).unwrap();
            let want: Vec<usize> = vals
                .iter()
                .enumerate()
                .filter(|(_, &v)| (30..90).contains(&v))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(rep.result, want, "round {round}: {vals:?}");
        }
    }
}
