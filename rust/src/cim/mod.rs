//! CiM engines: the ADRA engine (the paper's contribution) and the
//! two-read near-memory baseline it is evaluated against.

pub mod adra;
pub mod aggregate;
pub mod baseline;
pub mod ops;
pub mod vector;

pub use adra::{AdraEngine, AnalogBackend, BehavioralBackend, ExactBackend};
pub use baseline::BaselineEngine;
pub use ops::{BoolFn, CimOp, CimResult, CimValue, Engine, EngineError, WordAddr};
pub use vector::{VectorEngine, VectorResult};
