//! The ADRA CiM engine: asymmetric dual-row activation + three-SA sensing
//! + the Fig. 3(d) compute modules, over either sensing family.
//!
//! Activations run through a **tiered kernel** (`SimConfig::tier`, see
//! DESIGN.md §9-§10): when decisions are provably deterministic the
//! digital tier serves dual-row ops as whole-row packed word-slice ops
//! over the array's shadow plane (sampled cross-validation against the
//! analog pipeline).  Under `vt_sigma > 0` the **masked digital** path
//! keeps the packed kernel hot: per-cell margin masks (classified at
//! construction / write time against the sense references) route the
//! deterministic majority of columns through the shadow plane and only
//! the marginal minority through the zero-allocation analog pipeline,
//! merging decisions by mask.  The analog tiers (`Lut`/`Exact`) run the
//! full analog pipeline.  All tiers report identical values and modeled
//! costs.
//!
//! The analog senseline evaluation is pluggable (`AnalogBackend`): the
//! behavioral device model serves the fast path; the PJRT runtime backend
//! (`runtime::PjrtBackend`) executes the AOT JAX/Pallas artifacts for
//! analog ground truth.  Both produce identical digital decisions — that
//! equivalence is asserted by the cross-validation integration test.

use crate::array::{plane_set_bit, plane_window, width_mask, FefetArray};
use crate::config::{SensingScheme, SimConfig};
use crate::energy::EnergyModel;
use crate::logic::{and_tree_equal, ripple_add_sub, CompareResult};
use crate::sensing::{CurrentRefs, CurrentSenseBank, SenseOut, VoltageRefs, VoltageSenseBank};

use super::ops::{BoolFn, CimOp, CimResult, CimValue, Engine, EngineError, WordAddr};

/// Pluggable analog evaluation of one dual-row activation.
pub trait AnalogBackend: Send {
    /// DC senseline currents per column (current sensing).
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64>;

    /// Final RBL voltages per column after the discharge window
    /// (voltage sensing), for total bitline capacitance `c_rbl`.
    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64>;

    /// `dc_isl` into a caller-owned buffer (cleared first).  Backends on
    /// the hot path override this to avoid the per-activation allocation;
    /// the default delegates to the allocating variant.
    #[allow(clippy::too_many_arguments)]
    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        *out = self.dc_isl(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2);
    }

    /// `transient_vfinal` into a caller-owned buffer (cleared first).
    #[allow(clippy::too_many_arguments)]
    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        *out = self.transient_vfinal(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl);
    }

    fn name(&self) -> &'static str;
}

/// Behavioral backend: the Rust device model (fast path).
///
/// §Perf: evaluations go through the separable `CellLut` tables
/// (`device::lut`), which match the exact model to < 1e-5 relative — see
/// EXPERIMENTS.md §Perf for the before/after and `lut::tests` for the
/// accuracy pins.  The exact closed-form path remains available in
/// `device::{senseline_current, rbl_transient}` for validation.
pub struct BehavioralBackend {
    params: crate::config::DeviceParams,
    lut: crate::device::CellLut,
    /// lazily-built O(1) transient table, keyed by the c_rbl it was built
    /// for (engines pass a fixed c_rbl, so this builds exactly once).
    transient: Option<crate::device::lut::TransientTable>,
}

impl BehavioralBackend {
    pub fn new(params: &crate::config::DeviceParams) -> Self {
        Self {
            params: params.clone(),
            lut: crate::device::CellLut::new(params),
            transient: None,
        }
    }

    /// Build (or rebuild) the transient table for this `c_rbl`; a no-op
    /// when the cached table is already current.
    fn ensure_transient(&mut self, c_rbl: f64) {
        let stale = match &self.transient {
            Some(t) => t.c_rbl != c_rbl || t.v0 != self.params.v_read,
            None => true,
        };
        if stale {
            self.transient = Some(crate::device::lut::TransientTable::new(
                &self.params,
                &self.lut,
                self.params.v_read,
                c_rbl,
            ));
        }
    }
}

impl AnalogBackend for BehavioralBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.dc_isl_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, &mut out);
        out
    }

    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        let s = self.lut.s(self.params.v_read);
        out.clear();
        for i in 0..pol_a.len() {
            let fa = self.lut.f(self.lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64));
            let fb = self.lut.f(self.lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64));
            out.push((fa + fb) * s);
        }
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.transient_vfinal_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl, &mut out);
        out
    }

    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        self.ensure_transient(c_rbl);
        let table = self.transient.as_ref().expect("transient table built");
        let lut = &self.lut;
        out.clear();
        for i in 0..pol_a.len() {
            let f = lut.f(lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64))
                + lut.f(lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64));
            out.push(table.v_final(f));
        }
    }

    fn name(&self) -> &'static str {
        "behavioral"
    }
}

/// Exact-model backend (`FidelityTier::Exact`): the closed-form device
/// equations, no LUT approximation.  Slow; used for validation and as the
/// reference the faster tiers are pinned against.
pub struct ExactBackend {
    params: crate::config::DeviceParams,
}

impl ExactBackend {
    pub fn new(params: &crate::config::DeviceParams) -> Self {
        Self { params: params.clone() }
    }
}

impl AnalogBackend for ExactBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.dc_isl_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, &mut out);
        out
    }

    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        let p = &self.params;
        out.clear();
        for i in 0..pol_a.len() {
            out.push(crate::device::senseline_current(
                p,
                pol_a[i] as f64,
                pol_b[i] as f64,
                vg1,
                vg2,
                p.v_read,
                dvt_a[i] as f64,
                dvt_b[i] as f64,
            ));
        }
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.transient_vfinal_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl, &mut out);
        out
    }

    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        let p = &self.params;
        out.clear();
        for i in 0..pol_a.len() {
            out.push(
                crate::device::rbl_transient(
                    p,
                    pol_a[i] as f64,
                    pol_b[i] as f64,
                    vg1,
                    vg2,
                    p.v_read,
                    c_rbl,
                    dvt_a[i] as f64,
                    dvt_b[i] as f64,
                )
                .v_final,
            );
        }
    }

    fn name(&self) -> &'static str {
        "exact"
    }
}

/// Reusable per-engine buffers: both the analog pipeline and the packed
/// row planes run allocation-free after warmup (`planes_into` -> `*_into`
/// backend eval -> `sense_into`; packed paths reuse the `u64` plane
/// vectors below).
#[derive(Default)]
struct EngineScratch {
    pol_a: Vec<f32>,
    pol_b: Vec<f32>,
    dvt_a: Vec<f32>,
    dvt_b: Vec<f32>,
    /// Backend output: I_SL (current sensing) or V_final (voltage).
    analog: Vec<f64>,
    /// Per-column sense decisions of the latest activation.
    sense: Vec<SenseOut>,
    /// Packed row planes of the latest packed activation, window-relative
    /// (bit 0 = column `planes_lo`): operand bits of each row...
    packed_a: Vec<u64>,
    packed_b: Vec<u64>,
    /// ...decision planes (masked mode only; the pure digital tier
    /// derives `or`/`and` from the operand planes on demand)...
    p_or: Vec<u64>,
    p_and: Vec<u64>,
    /// ...and the deterministic-column mask (`mask_a & mask_b`).
    p_det: Vec<u64>,
    /// Absolute column indices the masked path routed through the analog
    /// pipeline (the marginal minority), and their sense decisions.
    marginal_cols: Vec<usize>,
    marginal_sense: Vec<SenseOut>,
    /// Column span the planes cover.
    planes_lo: usize,
    planes_hi: usize,
    /// Planes carry merged analog decisions (masked mode) vs operand
    /// bits only (pure digital mode).
    planes_masked: bool,
    /// Every merged analog triple is consistent with some (A, B) pair —
    /// word arithmetic on the operand planes then equals the ripple
    /// chain bit for bit.  The engine's own sense banks are thermometer
    /// comparators, so this only goes false for an exotic backend.
    planes_consistent: bool,
}

/// What one dual-row activation produced: packed operand words straight
/// from the digital shadow plane, or per-column sense outputs left in the
/// engine scratch by an analog tier.
enum Sensed {
    Digital(u64, u64),
    Analog,
}

/// The full ADRA engine.
pub struct AdraEngine {
    cfg: SimConfig,
    array: FefetArray,
    energy: EnergyModel,
    cur_bank: CurrentSenseBank,
    volt_bank: VoltageSenseBank,
    backend: Box<dyn AnalogBackend>,
    /// fast separable device tables for the single-row read path (§Perf).
    lut: crate::device::CellLut,
    scratch: EngineScratch,
    /// Digital tier engaged: `cfg.tier == Digital`, `vt_sigma == 0`, and
    /// the one-time margin check against the analog references passed.
    digital_ok: bool,
    /// Masked digital path engaged: `cfg.tier == Digital`, `vt_sigma > 0`,
    /// a classified margin-mask plane with a workable deterministic
    /// fraction, and the nominal margin check passed.
    masked_ok: bool,
    /// Digital activations since construction (drives xval sampling).
    xval_tick: u64,
}

/// What one packed-capable activation produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum RowActivation {
    /// Packed planes covering the span sit in the engine scratch and are
    /// consistent — derive ops with word arithmetic.
    Packed,
    /// Per-column sense decisions sit in the engine scratch (analog
    /// tiers, or a demoted inconsistent packed window).
    Sense,
}

impl AdraEngine {
    /// Every `XVAL_PERIOD`-th digital activation re-runs the analog
    /// pipeline and compares decisions (`ArrayStats::xval_*`).
    pub const XVAL_PERIOD: u64 = 64;

    /// Minimum deterministic-cell fraction for the masked path to engage:
    /// below this the per-column gather costs more than the plain analog
    /// pipeline it would replace (e.g. small-array voltage sensing, whose
    /// dual-row levels compress to nanovolts).
    pub const MASKED_MIN_DET_FRACTION: f64 = 0.05;

    /// Engine with the analog backend selected by `cfg.tier`
    /// (`Digital`/`Lut` -> LUT behavioral model, `Exact` -> closed form).
    /// The digital fast path engages only here, after calibration proves
    /// decisions deterministic; under variation the masked path engages
    /// instead when a margin-mask plane was classified
    /// (`SimConfig::mask_policy`) and enough of the array is
    /// deterministic to be worth serving packed.
    ///
    /// A masked-capable Digital engine takes the EXACT backend: its
    /// analog pipeline only ever evaluates the marginal minority, which
    /// by definition sits near the sense references — exactly where the
    /// LUT's approximation error could flip a decision.  Closed form for
    /// the few marginal columns keeps the masked tier bit-identical to
    /// `Exact` by construction while the deterministic majority stays on
    /// the packed planes.
    pub fn new(cfg: &SimConfig) -> Self {
        let masked_candidate = cfg.tier == crate::config::FidelityTier::Digital
            && cfg.vt_sigma > 0.0
            && cfg.mask_policy != crate::config::MaskPolicy::Off;
        let backend: Box<dyn AnalogBackend> = match cfg.tier {
            crate::config::FidelityTier::Exact => Box::new(ExactBackend::new(&cfg.device)),
            _ if masked_candidate => Box::new(ExactBackend::new(&cfg.device)),
            _ => Box::new(BehavioralBackend::new(&cfg.device)),
        };
        let mut e = Self::with_backend(cfg, backend);
        if cfg.tier == crate::config::FidelityTier::Digital {
            if cfg.vt_sigma == 0.0 {
                e.digital_ok = e.margin_check();
            } else if e.array.has_mask()
                && e.array.deterministic_fraction() >= Self::MASKED_MIN_DET_FRACTION
            {
                e.masked_ok = e.margin_check();
            }
        }
        if masked_candidate && !e.masked_ok {
            // masked path declined (collapsed margins or failed check):
            // restore the Lut-tier pipeline so the full-analog fallback
            // costs what the Lut tier costs
            e.backend = Box::new(BehavioralBackend::new(&cfg.device));
        }
        e
    }

    /// Engine with a custom analog backend (e.g. the PJRT artifact path).
    /// An explicit backend always runs the analog pipeline — the caller
    /// asked for that backend to be exercised, so the digital shortcut
    /// stays off regardless of `cfg.tier`.
    pub fn with_backend(cfg: &SimConfig, backend: Box<dyn AnalogBackend>) -> Self {
        let p = &cfg.device;
        let c_rbl = cfg.c_rbl();
        Self {
            cfg: cfg.clone(),
            array: FefetArray::new(cfg),
            energy: EnergyModel::new(cfg),
            cur_bank: CurrentSenseBank::new(CurrentRefs::derive(p, p.v_gread1, p.v_gread2)),
            volt_bank: VoltageSenseBank::new(VoltageRefs::derive(
                p, p.v_gread1, p.v_gread2, c_rbl,
            )),
            backend,
            lut: crate::device::CellLut::new(p),
            scratch: EngineScratch::default(),
            digital_ok: false,
            masked_ok: false,
            xval_tick: 0,
        }
    }

    /// The configured fidelity tier.
    pub fn tier(&self) -> crate::config::FidelityTier {
        self.cfg.tier
    }

    /// Is the bit-packed digital fast path serving activations?
    pub fn digital_active(&self) -> bool {
        self.digital_ok
    }

    /// Is the variation-aware masked packed path serving activations?
    pub fn masked_active(&self) -> bool {
        self.masked_ok
    }

    /// Either packed mode (full digital or masked) engaged?
    pub fn packed_active(&self) -> bool {
        self.digital_ok || self.masked_ok
    }

    /// One-time calibration: push the four (A,B) corner vectors (and the
    /// single-read levels) through THIS engine's analog backend and sense
    /// banks, and require every decision to decode correctly.  With
    /// `vt_sigma == 0` the analog pipeline is a pure function of the
    /// stored bits, so passing here proves the packed digital decisions
    /// are identical to the analog tier's.
    fn margin_check(&mut self) -> bool {
        let p = self.cfg.device.clone();
        let c_rbl = self.cfg.c_rbl();
        let mut ok = true;
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            let pol_a = [p.pol_of_bit(a) as f32];
            let pol_b = [p.pol_of_bit(b) as f32];
            let z = [0.0f32];
            let out = match self.cfg.scheme {
                SensingScheme::Current => {
                    self.backend.dc_isl_into(
                        &pol_a, &pol_b, &z, &z, p.v_gread1, p.v_gread2,
                        &mut self.scratch.analog,
                    );
                    self.cur_bank.sense(self.scratch.analog[0])
                }
                SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                    self.backend.transient_vfinal_into(
                        &pol_a, &pol_b, &z, &z, p.v_gread1, p.v_gread2, c_rbl,
                        &mut self.scratch.analog,
                    );
                    self.volt_bank.sense(self.scratch.analog[0])
                }
            };
            ok &= out.or == (a || b) && out.b == b && out.and == (a && b) && out.a() == a;
        }
        // the single-row read decision must be deterministic too
        let s = self.lut.s(p.v_read);
        let i_lrs = self.lut.f(self.lut.u_of(p.v_gread2, p.pol_of_bit(true), 0.0)) * s;
        let i_hrs = self.lut.f(self.lut.u_of(p.v_gread2, p.pol_of_bit(false), 0.0)) * s;
        ok && self.cur_bank.sense_read(i_lrs) && !self.cur_bank.sense_read(i_hrs)
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn array(&self) -> &FefetArray {
        &self.array
    }

    pub fn array_mut(&mut self) -> &mut FefetArray {
        &mut self.array
    }

    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    fn check_word(&self, row: usize, word: usize) -> Result<(), EngineError> {
        if row >= self.cfg.rows || word >= self.cfg.words_per_row() {
            return Err(EngineError::OutOfRange(format!(
                "row {row} word {word} (array {}x{} words/row {})",
                self.cfg.rows,
                self.cfg.cols,
                self.cfg.words_per_row()
            )));
        }
        Ok(())
    }

    fn word_cols(&self, word: usize) -> (usize, usize) {
        let lo = word * self.cfg.word_bits;
        (lo, lo + self.cfg.word_bits)
    }

    /// Validate one dual-row activation's addressing.
    fn check_pair(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> Result<(), EngineError> {
        if row_a == row_b {
            return Err(EngineError::Unsupported(
                "dual-row activation requires two distinct rows".into(),
            ));
        }
        if row_a >= self.cfg.rows
            || row_b >= self.cfg.rows
            || col_lo >= col_hi
            || col_hi > self.cfg.cols
        {
            return Err(EngineError::OutOfRange(format!(
                "rows {row_a}/{row_b} cols {col_lo}..{col_hi} (array {}x{})",
                self.cfg.rows, self.cfg.cols
            )));
        }
        Ok(())
    }

    /// Run the zero-allocation analog pipeline for `[lo, hi)` of the row
    /// pair: planes -> backend eval -> sense bank, all into the engine
    /// scratch.  Purely computational — no stats.
    fn fill_sense_analog(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        let vg1 = self.cfg.device.v_gread1;
        let vg2 = self.cfg.device.v_gread2;
        self.array.planes_into(
            row_a,
            row_b,
            lo,
            hi,
            &mut self.scratch.pol_a,
            &mut self.scratch.pol_b,
            &mut self.scratch.dvt_a,
            &mut self.scratch.dvt_b,
        );
        match self.cfg.scheme {
            SensingScheme::Current => {
                self.backend.dc_isl_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    &mut self.scratch.analog,
                );
                self.cur_bank.sense_into(&self.scratch.analog, &mut self.scratch.sense);
            }
            SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                let c_rbl = self.cfg.c_rbl();
                self.backend.transient_vfinal_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    c_rbl,
                    &mut self.scratch.analog,
                );
                self.volt_bank.sense_into(&self.scratch.analog, &mut self.scratch.sense);
            }
        }
    }

    /// Build the packed row planes for `[lo, hi)` of the row pair in a
    /// single pass over `u64` word slices: operand bits straight from the
    /// shadow plane, and — in masked mode — the deterministic-column mask
    /// `mask_a & mask_b` plus analog decisions for the marginal minority,
    /// gathered into ONE compact backend evaluation and merged back into
    /// the planes by mask.  A 1024-column row costs ~16 word ops plus the
    /// marginal gather, not 1024 per-column pushes.  Purely
    /// computational — no stats.
    fn fill_planes(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(), EngineError> {
        self.scratch.packed_a.clear();
        self.scratch.packed_b.clear();
        self.scratch.p_or.clear();
        self.scratch.p_and.clear();
        self.scratch.p_det.clear();
        self.scratch.marginal_cols.clear();
        self.scratch.planes_lo = lo;
        self.scratch.planes_hi = hi;
        self.scratch.planes_masked = self.masked_ok;
        self.scratch.planes_consistent = true;
        let mut c = lo;
        while c < hi {
            let w = (hi - c).min(64);
            let a = self.array.packed_window(row_a, c, c + w);
            let b = self.array.packed_window(row_b, c, c + w);
            self.scratch.packed_a.push(a);
            self.scratch.packed_b.push(b);
            if self.masked_ok {
                let det = self.array.mask_window(row_a, c, c + w)
                    & self.array.mask_window(row_b, c, c + w)
                    & width_mask(w);
                self.scratch.p_det.push(det);
                self.scratch.p_or.push(a | b);
                self.scratch.p_and.push(a & b);
                let mut marg = !det & width_mask(w);
                while marg != 0 {
                    let i = marg.trailing_zeros() as usize;
                    self.scratch.marginal_cols.push(c + i);
                    marg &= marg - 1;
                }
            }
            c += w;
        }
        if self.masked_ok && !self.scratch.marginal_cols.is_empty() {
            self.sense_marginal_cols(row_a, row_b)?;
        }
        Ok(())
    }

    /// Run the analog pipeline over the gathered marginal columns of the
    /// current planes and merge each decision back by mask.
    fn sense_marginal_cols(&mut self, row_a: usize, row_b: usize) -> Result<(), EngineError> {
        self.scratch.pol_a.clear();
        self.scratch.pol_b.clear();
        self.scratch.dvt_a.clear();
        self.scratch.dvt_b.clear();
        for k in 0..self.scratch.marginal_cols.len() {
            let col = self.scratch.marginal_cols[k];
            self.scratch.pol_a.push(self.array.pol(row_a, col) as f32);
            self.scratch.pol_b.push(self.array.pol(row_b, col) as f32);
            self.scratch.dvt_a.push(self.array.dvt(row_a, col) as f32);
            self.scratch.dvt_b.push(self.array.dvt(row_b, col) as f32);
        }
        let vg1 = self.cfg.device.v_gread1;
        let vg2 = self.cfg.device.v_gread2;
        match self.cfg.scheme {
            SensingScheme::Current => {
                self.backend.dc_isl_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    &mut self.scratch.analog,
                );
                self.cur_bank.sense_into(&self.scratch.analog, &mut self.scratch.marginal_sense);
            }
            SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                let c_rbl = self.cfg.c_rbl();
                self.backend.transient_vfinal_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    c_rbl,
                    &mut self.scratch.analog,
                );
                self.volt_bank.sense_into(&self.scratch.analog, &mut self.scratch.marginal_sense);
            }
        }
        for k in 0..self.scratch.marginal_cols.len() {
            let col = self.scratch.marginal_cols[k];
            let off = col - self.scratch.planes_lo;
            let o = self.scratch.marginal_sense[k];
            if o.and && !o.or {
                return Err(EngineError::SenseFailure(format!(
                    "column {off}: AND asserted without OR — margin collapse"
                )));
            }
            let a = o.a();
            plane_set_bit(&mut self.scratch.packed_a, off, a);
            plane_set_bit(&mut self.scratch.packed_b, off, o.b);
            plane_set_bit(&mut self.scratch.p_or, off, o.or);
            plane_set_bit(&mut self.scratch.p_and, off, o.and);
            if o.or != (a || o.b) || o.and != (a && o.b) {
                self.scratch.planes_consistent = false;
            }
        }
        Ok(())
    }

    /// The (OR, B, AND) decision triple of one plane column
    /// (window-relative bit offset) — the single derivation shared by
    /// sense materialization and cross-validation so the two can never
    /// diverge.
    fn plane_triple(&self, off: usize) -> SenseOut {
        let w = off / 64;
        let m = 1u64 << (off % 64);
        if self.scratch.planes_masked {
            SenseOut {
                or: self.scratch.p_or[w] & m != 0,
                b: self.scratch.packed_b[w] & m != 0,
                and: self.scratch.p_and[w] & m != 0,
            }
        } else {
            let a = self.scratch.packed_a[w] & m != 0;
            let b = self.scratch.packed_b[w] & m != 0;
            SenseOut { or: a || b, b, and: a && b }
        }
    }

    /// Rebuild per-column `SenseOut`s for `[lo, hi)` (within the planes
    /// span) from the packed planes — the legacy borrow-of-scratch API
    /// of `activate_cols`/`activate_word`.
    fn sense_from_planes(&mut self, lo: usize, hi: usize) {
        debug_assert!(lo >= self.scratch.planes_lo && hi <= self.scratch.planes_hi);
        let base = self.scratch.planes_lo;
        self.scratch.sense.clear();
        for c in lo..hi {
            let o = self.plane_triple(c - base);
            self.scratch.sense.push(o);
        }
    }

    /// Sanity on the analog decode: an OR=0/AND=1 column means the
    /// margins collapsed.
    fn check_margins(&self) -> Result<(), EngineError> {
        for (i, o) in self.scratch.sense.iter().enumerate() {
            if o.and && !o.or {
                return Err(EngineError::SenseFailure(format!(
                    "column {i}: AND asserted without OR — margin collapse"
                )));
            }
        }
        Ok(())
    }

    /// Sampled cross-validation of the packed paths: every
    /// `XVAL_PERIOD`-th packed activation re-runs the analog pipeline
    /// over the same window and compares every column's (OR, B, AND)
    /// decision against the packed planes (which hold the shadow-derived
    /// decisions for deterministic columns and the already-analog
    /// decisions for marginal ones).  Counts in `ArrayStats`.
    ///
    /// Precondition: the planes cover `[lo, hi)`.
    fn maybe_cross_validate(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        self.xval_tick += 1;
        if self.xval_tick % Self::XVAL_PERIOD != 0 {
            return;
        }
        self.fill_sense_analog(row_a, row_b, lo, hi);
        let mut mismatch = false;
        for (i, c) in (lo..hi).enumerate() {
            let served = self.plane_triple(c - self.scratch.planes_lo);
            if self.scratch.sense[i] != served {
                mismatch = true;
            }
        }
        let stats = self.array.stats_mut();
        stats.xval_checks += 1;
        if mismatch {
            stats.xval_mismatches += 1;
        }
        crate::observe::recorder().record_xval(mismatch);
    }

    /// Shared packed-path bookkeeping for one activation over `[lo, hi)`
    /// against the current planes: tier + deterministic-fraction counters
    /// (given the window's marginal-column count) and sampled
    /// cross-validation.  Every packed activation — whole-span or fused
    /// group — goes through here, so batched and unbatched accounting
    /// can never diverge.  NOTE: clobbers `scratch.sense` when the
    /// sampled cross-validation fires — materialize sense AFTER this.
    fn packed_bookkeeping(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize, marg: u64) {
        let width = (hi - lo) as u64;
        let masked = self.scratch.planes_masked;
        {
            let stats = self.array.stats_mut();
            stats.det_cols += width - marg;
            stats.marginal_cols += marg;
            if marg == 0 {
                stats.digital_activations += 1;
            }
            if masked {
                stats.masked_activations += 1;
            }
        }
        self.maybe_cross_validate(row_a, row_b, lo, hi);
    }

    /// Shared analog-path activation: zero-allocation pipeline into
    /// scratch + margin sanity.  Every analog activation goes through
    /// here.
    fn analog_activate(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(), EngineError> {
        self.fill_sense_analog(row_a, row_b, lo, hi);
        self.check_margins()
    }

    /// One dual-row activation over an arbitrary span `[lo, hi)` — the
    /// single-pass word-slice primitive every packed consumer builds on
    /// (scalar ops, row-wide vector ops, fused batches).  Records stats.
    /// After `Packed`, consistent planes covering the span sit in the
    /// engine scratch; after `Sense`, per-column decisions do.
    pub(crate) fn activate_span(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<RowActivation, EngineError> {
        self.check_pair(row_a, row_b, lo, hi)?;
        self.note_dual_access(lo, hi);
        // kernel-tier trace hook: pre-check the flag so the packed fast
        // path pays one relaxed atomic load when tracing is off
        let rec = crate::observe::recorder();
        if self.digital_ok || self.masked_ok {
            self.fill_planes(row_a, row_b, lo, hi)?;
            let marg = self.scratch.marginal_cols.len() as u64;
            self.packed_bookkeeping(row_a, row_b, lo, hi, marg);
            if rec.kernel_enabled() {
                let route = if self.scratch.planes_masked {
                    crate::observe::KernelRoute::Masked
                } else {
                    crate::observe::KernelRoute::Digital
                };
                rec.record_kernel(route, row_a, row_b, hi - lo, marg as usize);
            }
            if self.scratch.planes_consistent {
                Ok(RowActivation::Packed)
            } else {
                // an inconsistent analog decode in a marginal column:
                // demote the whole span to the sense representation so
                // derivations stay bit-identical with the analog tiers
                self.sense_from_planes(lo, hi);
                Ok(RowActivation::Sense)
            }
        } else {
            self.analog_activate(row_a, row_b, lo, hi)?;
            if rec.kernel_enabled() {
                let route = if self.cfg.tier == crate::config::FidelityTier::Exact {
                    crate::observe::KernelRoute::Exact
                } else {
                    crate::observe::KernelRoute::Analog
                };
                rec.record_kernel(route, row_a, row_b, hi - lo, hi - lo);
            }
            Ok(RowActivation::Sense)
        }
    }

    /// One dual-row activation over `[lo, hi)`: records stats, leaves the
    /// per-column sense decisions in `scratch.sense` (either tier).
    fn sense_cols(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(), EngineError> {
        match self.activate_span(row_a, row_b, lo, hi)? {
            RowActivation::Packed => {
                self.sense_from_planes(lo, hi);
                Ok(())
            }
            RowActivation::Sense => Ok(()),
        }
    }

    /// The scalar-op activation: the packed paths return the operand
    /// words directly (no per-column materialization at all); the analog
    /// tiers leave sense outputs in scratch.
    fn activate(&mut self, row_a: usize, row_b: usize, word: usize) -> Result<Sensed, EngineError> {
        let (lo, hi) = self.word_cols(word);
        match self.activate_span(row_a, row_b, lo, hi)? {
            RowActivation::Packed => {
                let wb = hi - lo;
                let a = plane_window(&self.scratch.packed_a, 0, wb);
                let b = plane_window(&self.scratch.packed_b, 0, wb);
                Ok(Sensed::Digital(a, b))
            }
            RowActivation::Sense => Ok(Sensed::Analog),
        }
    }

    fn note_dual_access(&mut self, lo: usize, hi: usize) {
        // FefetArray::planes_into doesn't mutate stats; account the
        // activation here so every tier/backend is counted identically.
        let cols = self.array.cols();
        let s = self.array_stats_mut();
        s.dual_activations += 1;
        s.half_selected_cols += (cols - (hi - lo)) as u64;
    }

    fn array_stats_mut(&mut self) -> &mut crate::array::ArrayStats {
        // small helper: FefetArray exposes stats by value; keep a shadow
        // counter through reset/read (see ArrayStats usage in tests).
        // Implemented via interior access on the array.
        self.array.stats_mut()
    }

    /// Public access to one dual-row activation + sensing over a word
    /// window.  Counts one array activation.  Returns an owned vector
    /// (one allocation per call) — hot paths should prefer
    /// `activate_cols`, which returns a borrow of the engine scratch.
    pub fn activate_word(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Vec<SenseOut>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        let (lo, hi) = self.word_cols(word);
        self.check_pair(row_a, row_b, lo, hi)?;
        self.sense_cols(row_a, row_b, lo, hi)?;
        Ok(self.scratch.sense.clone())
    }

    /// One dual-row activation sensing an arbitrary column window (the
    /// wordlines span the whole row anyway): ONE recorded activation,
    /// `cols - (col_hi - col_lo)` half-selected columns, sense outputs
    /// for every addressed column.  Returns a borrow of the engine's
    /// sense scratch — copy out before the next activation.
    pub fn activate_cols(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> Result<&[SenseOut], EngineError> {
        self.check_pair(row_a, row_b, col_lo, col_hi)?;
        self.sense_cols(row_a, row_b, col_lo, col_hi)?;
        Ok(&self.scratch.sense)
    }

    /// One dual-row activation sensing EVERY column of the row pair —
    /// the single-call row API the vector engine builds on.  Exactly one
    /// dual activation and zero half-selected columns are recorded.
    pub fn activate_row(
        &mut self,
        row_a: usize,
        row_b: usize,
    ) -> Result<&[SenseOut], EngineError> {
        let cols = self.cfg.cols;
        self.activate_cols(row_a, row_b, 0, cols)
    }

    /// Assemble words from per-bit sense outputs.
    fn words_from(outs: &[SenseOut]) -> (u64, u64) {
        let mut a = 0u64;
        let mut b = 0u64;
        for (i, o) in outs.iter().enumerate() {
            if o.a() {
                a |= 1 << i;
            }
            if o.b {
                b |= 1 << i;
            }
        }
        (a, b)
    }

    fn bool_from(f: BoolFn, outs: &[SenseOut]) -> u64 {
        let mut v = 0u64;
        for (i, o) in outs.iter().enumerate() {
            let bit = match f {
                BoolFn::And => o.and,
                BoolFn::Or => o.or,
                BoolFn::Nand => !o.and,
                BoolFn::Nor => !o.or,
                BoolFn::Xor => o.xor(),
                BoolFn::Xnor => !o.xor(),
                BoolFn::AndNot => o.a() && !o.b,
                BoolFn::OrNot => o.a() || !o.b,
            };
            if bit {
                v |= 1 << i;
            }
        }
        v
    }

    /// Standard single-row read through the sensing path (LUT-fast; the
    /// digital tier serves it straight from the shadow plane — the read
    /// decode was proven deterministic by the margin check).  The masked
    /// path serves mask-certified cells from the shadow and decodes only
    /// the marginal ones analog, merging by mask.
    fn read_word_sensed(&mut self, addr: WordAddr) -> Result<u64, EngineError> {
        self.check_word(addr.row, addr.word)?;
        let (lo, hi) = self.word_cols(addr.word);
        let n = hi - lo;
        self.array.stats_mut().reads += 1;
        if self.digital_ok {
            self.array.stats_mut().det_cols += n as u64;
            return Ok(self.array.packed_window(addr.row, lo, hi));
        }
        if self.masked_ok {
            let det = self.array.mask_window(addr.row, lo, hi) & width_mask(n);
            let mut v = self.array.packed_window(addr.row, lo, hi) & det;
            let det_count = det.count_ones() as u64;
            {
                let stats = self.array.stats_mut();
                stats.det_cols += det_count;
                stats.marginal_cols += n as u64 - det_count;
            }
            let mut marg = !det & width_mask(n);
            while marg != 0 {
                let i = marg.trailing_zeros() as usize;
                if self.read_bit_analog(addr.row, lo + i) {
                    v |= 1 << i;
                }
                marg &= marg - 1;
            }
            return Ok(v);
        }
        let vg = self.cfg.device.v_gread2;
        let s = self.lut.s(self.cfg.device.v_read);
        let mut v = 0u64;
        for (i, c) in (lo..hi).enumerate() {
            let i_cell = self.lut.f(self.lut.u_of(
                vg,
                self.array.pol(addr.row, c),
                self.array.dvt(addr.row, c),
            )) * s;
            if self.cur_bank.sense_read(i_cell) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    /// One cell's single-row read decision through the LUT + read
    /// reference — shared by the analog read path and the masked path's
    /// marginal bits.
    fn read_bit_analog(&self, row: usize, col: usize) -> bool {
        let vg = self.cfg.device.v_gread2;
        let s = self.lut.s(self.cfg.device.v_read);
        let i_cell =
            self.lut.f(self.lut.u_of(vg, self.array.pol(row, col), self.array.dvt(row, col))) * s;
        self.cur_bank.sense_read(i_cell)
    }

    /// All-ones mask of a word's width (the shared helper owns the
    /// `n == 64` shift-overflow guard).
    #[inline]
    fn word_mask(bits: usize) -> u64 {
        width_mask(bits)
    }

    /// Two's-complement interpretation of an n-bit word.
    #[inline]
    pub(crate) fn signed_of(v: u64, bits: usize) -> i128 {
        let sign = 1u64 << (bits - 1);
        if v & sign != 0 {
            v as i128 - (1i128 << bits)
        } else {
            v as i128
        }
    }

    /// Two's-complement interpretation of an n-bit value, n <= 127 —
    /// the wide-operand variant the multi-word carry chain uses.
    #[inline]
    pub(crate) fn signed_of_wide(v: u128, bits: usize) -> i128 {
        debug_assert!(bits >= 1 && bits <= 127);
        if v & (1u128 << (bits - 1)) != 0 {
            v as i128 - (1i128 << bits)
        } else {
            v as i128
        }
    }

    /// Evaluate a dual-row op from the packed operand words — the digital
    /// tier's op derivation, shared by `execute` and the fused datapath.
    /// Returns `None` for ops that are not dual-row.
    pub(crate) fn digital_value(op: &CimOp, a: u64, b: u64, word_bits: usize) -> Option<CimValue> {
        Some(match *op {
            CimOp::Read2 { .. } => CimValue::Pair(a, b),
            CimOp::Bool { f, .. } => CimValue::Word(f.apply(a, b, Self::word_mask(word_bits))),
            // the packed sum equals the ripple chain's (n+1)-bit unsigned
            // result exactly; sub/compare match its signed semantics
            CimOp::Add { .. } => CimValue::Sum(a as u128 + b as u128),
            CimOp::Sub { .. } => {
                CimValue::Diff(Self::signed_of(a, word_bits) - Self::signed_of(b, word_bits))
            }
            CimOp::Compare { .. } => CimValue::Ordering(if a == b {
                CompareResult::Equal
            } else if Self::signed_of(a, word_bits) < Self::signed_of(b, word_bits) {
                CompareResult::Less
            } else {
                CompareResult::Greater
            }),
            CimOp::Read(_) | CimOp::Write { .. } => return None,
        })
    }

    /// Evaluate a dual-row op from per-column sense outputs — the analog
    /// tiers' op derivation, shared by `execute` and the fused datapath.
    pub(crate) fn analog_value(op: &CimOp, outs: &[SenseOut]) -> CimValue {
        match *op {
            CimOp::Read2 { .. } => {
                let (a, b) = Self::words_from(outs);
                CimValue::Pair(a, b)
            }
            CimOp::Bool { f, .. } => CimValue::Word(Self::bool_from(f, outs)),
            CimOp::Add { .. } => CimValue::Sum(ripple_add_sub(outs, false).as_unsigned()),
            CimOp::Sub { .. } => CimValue::Diff(ripple_add_sub(outs, true).as_signed()),
            CimOp::Compare { .. } => {
                let diff = ripple_add_sub(outs, true);
                CimValue::Ordering(if and_tree_equal(&diff.bits) {
                    CompareResult::Equal
                } else if diff.sign() {
                    CompareResult::Less
                } else {
                    CompareResult::Greater
                })
            }
            CimOp::Read(_) | CimOp::Write { .. } => {
                unreachable!("only dual-row ops go through sensing")
            }
        }
    }

    /// Packed operand window `[c_lo, c_hi)` (absolute columns, <= 64
    /// wide) of the planes left by the latest packed activation.
    pub(crate) fn planes_window(&self, c_lo: usize, c_hi: usize) -> (u64, u64) {
        let off = c_lo - self.scratch.planes_lo;
        let n = c_hi - c_lo;
        debug_assert!(c_lo >= self.scratch.planes_lo && c_hi <= self.scratch.planes_hi);
        (
            plane_window(&self.scratch.packed_a, off, n),
            plane_window(&self.scratch.packed_b, off, n),
        )
    }

    /// Wide packed operand window (up to 127 bits) — two chunked `u64`
    /// extractions per operand, for the multi-word carry chain.
    pub(crate) fn planes_window_wide(&self, c_lo: usize, c_hi: usize) -> (u128, u128) {
        let n = c_hi - c_lo;
        debug_assert!(n >= 1 && n <= 127);
        if n <= 64 {
            let (a, b) = self.planes_window(c_lo, c_lo + n);
            return (a as u128, b as u128);
        }
        let (a_lo, b_lo) = self.planes_window(c_lo, c_lo + 64);
        let (a_hi, b_hi) = self.planes_window(c_lo + 64, c_hi);
        (
            a_lo as u128 | ((a_hi as u128) << 64),
            b_lo as u128 | ((b_hi as u128) << 64),
        )
    }

    /// Prepare the packed planes for a fused pair batch spanning
    /// `[lo, hi)` of one row pair.  Returns `false` when no packed mode
    /// is engaged (analog tiers / explicit backends) — the caller then
    /// activates per group exactly as before.  Records NO stats: each
    /// group of the batch records its own activation through
    /// `serve_group_from_planes`, so modeled accounting (activations,
    /// half-selects, costs, cross-validation cadence) is identical to
    /// unbatched execution; only the host-side plane fill is shared.
    pub(crate) fn prefill_pair_planes(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<bool, EngineError> {
        if !(self.digital_ok || self.masked_ok) {
            return Ok(false);
        }
        self.check_pair(row_a, row_b, lo, hi)?;
        self.fill_planes(row_a, row_b, lo, hi)?;
        Ok(true)
    }

    /// Serve one fused group (a word window) from planes prepared by
    /// `prefill_pair_planes`: records the group's own activation stats
    /// and sampled cross-validation, then returns the packed operand
    /// words — or `None` with the group's sense decisions left in
    /// scratch when the planes were demoted (inconsistent decode).
    pub(crate) fn serve_group_from_planes(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Option<(u64, u64)>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        let (lo, hi) = self.word_cols(word);
        debug_assert!(lo >= self.scratch.planes_lo && hi <= self.scratch.planes_hi);
        self.note_dual_access(lo, hi);
        let wb = (hi - lo) as u64;
        let off = lo - self.scratch.planes_lo;
        let marg = if self.scratch.planes_masked {
            wb - plane_window(&self.scratch.p_det, off, hi - lo).count_ones() as u64
        } else {
            0
        };
        self.packed_bookkeeping(row_a, row_b, lo, hi, marg);
        if self.scratch.planes_consistent {
            Ok(Some(self.planes_window(lo, hi)))
        } else {
            self.sense_from_planes(lo, hi);
            Ok(None)
        }
    }

    /// One dual-row activation for the fused datapath: the packed paths
    /// return the packed operand words (derive followers with
    /// `digital_value` — no per-column work at all); the analog tiers
    /// return `None` with the sense outputs left in the engine scratch
    /// (read them back with `last_sense`).
    pub(crate) fn activate_packed(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Option<(u64, u64)>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        match self.activate(row_a, row_b, word)? {
            Sensed::Digital(a, b) => Ok(Some((a, b))),
            Sensed::Analog => Ok(None),
        }
    }

    /// Sense outputs of the latest analog activation (valid until the
    /// next activation; the fused path reads this right after
    /// `activate_packed` returns `Ok(None)`).
    pub(crate) fn last_sense(&self) -> &[SenseOut] {
        &self.scratch.sense
    }
}

impl Engine for AdraEngine {
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError> {
        match *op {
            CimOp::Write { addr, value } => {
                self.check_word(addr.row, addr.word)?;
                self.array.write_word(addr.row, addr.word, value);
                Ok(CimResult { value: CimValue::None, cost: self.energy.write_cost() })
            }
            CimOp::Read(addr) => {
                let v = self.read_word_sensed(addr)?;
                Ok(CimResult { value: CimValue::Word(v), cost: self.energy.read_cost() })
            }
            CimOp::Read2 { row_a, row_b, word }
            | CimOp::Bool { row_a, row_b, word, .. }
            | CimOp::Add { row_a, row_b, word }
            | CimOp::Sub { row_a, row_b, word }
            | CimOp::Compare { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let wb = self.cfg.word_bits;
                let value = match self.activate(row_a, row_b, word)? {
                    Sensed::Digital(a, b) => {
                        Self::digital_value(op, a, b, wb).expect("dual-row op")
                    }
                    Sensed::Analog => Self::analog_value(op, &self.scratch.sense),
                };
                Ok(CimResult { value, cost: self.energy.cim_cost() })
            }
        }
    }

    /// ADRA has a native fused datapath: dual ops over the same operand
    /// pair share one asymmetric activation (`coordinator::fuse`).
    fn execute_fused(&mut self, ops: &[CimOp]) -> Option<Vec<Result<CimResult, EngineError>>> {
        Some(crate::coordinator::fuse::execute_fused(self, ops))
    }

    fn array_stats(&self) -> Option<crate::array::ArrayStats> {
        Some(self.array.stats())
    }

    fn name(&self) -> &'static str {
        "adra"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine(scheme: SensingScheme) -> AdraEngine {
        let mut cfg = SimConfig::square(256, scheme);
        cfg.word_bits = 8;
        AdraEngine::new(&cfg)
    }

    fn setup(e: &mut AdraEngine, a: u64, b: u64) {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    }

    #[test]
    fn read2_recovers_both_words_single_access() {
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            setup(&mut e, 0xA5, 0x3C);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(0xA5, 0x3C), "{scheme:?}");
        }
    }

    #[test]
    fn all_boolean_functions_correct() {
        let mut rng = Rng::new(11);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..8 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                for f in BoolFn::ALL {
                    let r = e
                        .execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 })
                        .unwrap();
                    assert_eq!(
                        r.value,
                        CimValue::Word(f.apply(a, b, 0xFF)),
                        "{scheme:?} {f:?} a={a:#x} b={b:#x}"
                    );
                }
            }
        }
    }

    #[test]
    fn add_and_sub_match_integers() {
        let mut rng = Rng::new(13);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..16 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                let add = e.execute(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }).unwrap();
                assert_eq!(add.value, CimValue::Sum((a + b) as u128));
                let sub = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
                let sa = (a as i128) - if a >= 128 { 256 } else { 0 };
                let sb = (b as i128) - if b >= 128 { 256 } else { 0 };
                assert_eq!(sub.value, CimValue::Diff(sa - sb), "a={a} b={b} {scheme:?}");
            }
        }
    }

    #[test]
    fn compare_matches_signed_order() {
        let mut e = engine(SensingScheme::Current);
        for (a, b, expect) in [
            (5u64, 9u64, CompareResult::Less),
            (9, 5, CompareResult::Greater),
            (7, 7, CompareResult::Equal),
            (0x80, 0x7F, CompareResult::Less), // -128 < 127
        ] {
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Ordering(expect), "a={a:#x} b={b:#x}");
        }
    }

    #[test]
    fn single_access_for_cim_ops() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 3, 5);
        e.array_mut().reset_stats();
        e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1, "subtraction must be ONE access");
        assert_eq!(s.reads, 0);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut e = engine(SensingScheme::Current);
        assert!(matches!(
            e.execute(&CimOp::Read(WordAddr { row: 9999, word: 0 })),
            Err(EngineError::OutOfRange(_))
        ));
        assert!(matches!(
            e.execute(&CimOp::Sub { row_a: 0, row_b: 0, word: 0 }),
            Err(EngineError::Unsupported(_))
        ));
    }

    #[test]
    fn standard_read_via_sense_path() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0xC3, 0);
        let r = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r.value, CimValue::Word(0xC3));
    }

    #[test]
    fn costs_attached_and_ordered() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 1, 2);
        let read = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        let cim = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert!(cim.cost.energy.total() > read.cost.energy.total());
        assert!(cim.cost.latency > read.cost.latency);
        // but FAR less than two reads (that's the point of the paper)
        assert!(cim.cost.energy.total() < 2.0 * read.cost.energy.total());
    }

    #[test]
    fn digital_tier_engages_on_default_config() {
        let e = engine(SensingScheme::Current);
        assert_eq!(e.tier(), crate::config::FidelityTier::Digital);
        assert!(e.digital_active(), "margin check must pass at the paper bias");
    }

    #[test]
    fn digital_activations_counted_as_subset() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0x5A, 0x0F);
        e.array_mut().reset_stats();
        for _ in 0..5 {
            e.execute(&CimOp::Bool { f: BoolFn::Or, row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 5);
        assert_eq!(s.digital_activations, 5, "digital tier must serve all of them");
        assert_eq!(s.xval_mismatches, 0);
    }

    #[test]
    fn lut_tier_serves_no_digital_activations() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.tier = crate::config::FidelityTier::Lut;
        let mut e = AdraEngine::new(&cfg);
        assert!(!e.digital_active());
        setup(&mut e, 0x5A, 0x0F);
        let r = e.execute(&CimOp::Bool { f: BoolFn::Xor, row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Word(0x55));
        assert_eq!(e.array().stats().digital_activations, 0);
    }

    #[test]
    fn explicit_backend_keeps_analog_pipeline() {
        let cfg = {
            let mut c = SimConfig::square(64, SensingScheme::Current);
            c.word_bits = 8;
            c
        };
        let mut e =
            AdraEngine::with_backend(&cfg, Box::new(BehavioralBackend::new(&cfg.device)));
        assert!(!e.digital_active(), "explicit backends must be exercised");
        setup(&mut e, 9, 4);
        let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(5));
        assert_eq!(e.array().stats().digital_activations, 0);
    }

    #[test]
    fn cross_validation_samples_and_agrees() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0xA5, 0x3C);
        let n = 3 * AdraEngine::XVAL_PERIOD;
        for _ in 0..n {
            e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let s = e.array().stats();
        assert!(s.xval_checks >= 3, "sampling must have triggered: {s:?}");
        assert_eq!(s.xval_mismatches, 0, "digital and analog tiers must agree");
    }

    #[test]
    fn activate_row_records_one_activation_no_half_selects() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 1, 2);
        e.array_mut().reset_stats();
        let cols = e.cfg().cols;
        let outs = e.activate_row(0, 1).unwrap();
        assert_eq!(outs.len(), cols);
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1);
        assert_eq!(s.half_selected_cols, 0, "full row: nothing is half-selected");
    }

    #[test]
    fn activate_cols_counts_half_selects_once() {
        let mut e = engine(SensingScheme::Current);
        e.array_mut().reset_stats();
        let cols = e.cfg().cols;
        let outs = e.activate_cols(0, 1, 8, 40).unwrap();
        assert_eq!(outs.len(), 32);
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1);
        assert_eq!(s.half_selected_cols, (cols - 32) as u64);
        assert!(matches!(e.activate_cols(0, 0, 0, 8), Err(EngineError::Unsupported(_))));
        assert!(matches!(e.activate_cols(0, 1, 8, 8), Err(EngineError::OutOfRange(_))));
    }

    #[test]
    fn works_with_variation() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.vt_sigma = 0.02; // 20 mV sigma
        let mut e = AdraEngine::new(&cfg);
        let mut rng = Rng::new(17);
        for _ in 0..16 {
            let (a, b) = (rng.below(256), rng.below(256));
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(a, b), "variation broke sensing");
        }
    }

    fn varied_cfg(policy: crate::config::MaskPolicy) -> SimConfig {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.vt_sigma = 0.02;
        cfg.mask_policy = policy;
        cfg
    }

    #[test]
    fn masked_path_engages_under_variation() {
        let e = AdraEngine::new(&varied_cfg(crate::config::MaskPolicy::Write));
        assert!(!e.digital_active(), "full digital needs vt_sigma == 0");
        assert!(e.masked_active(), "margin masks must keep the packed path hot");
        assert!(e.packed_active());
        assert!(e.array().deterministic_fraction() > 0.9);
    }

    #[test]
    fn mask_policy_off_restores_full_analog_fallback() {
        let mut e = AdraEngine::new(&varied_cfg(crate::config::MaskPolicy::Off));
        assert!(!e.masked_active() && !e.digital_active());
        setup(&mut e, 0x5A, 0x0F);
        e.execute(&CimOp::Bool { f: BoolFn::Or, row_a: 0, row_b: 1, word: 0 }).unwrap();
        let s = e.array().stats();
        assert_eq!(s.digital_activations, 0);
        assert_eq!(s.masked_activations, 0);
        assert_eq!(s.det_cols + s.marginal_cols, 0, "no packed columns at all");
    }

    #[test]
    fn masked_path_matches_analog_mirror() {
        // same seed -> same variation plane; the masked engine must be
        // bit-identical to a pure-analog (Exact) mirror on every op,
        // including single reads
        let cfg = varied_cfg(crate::config::MaskPolicy::Write);
        let mut masked = AdraEngine::new(&cfg);
        let mut mirror_cfg = cfg.clone();
        mirror_cfg.tier = crate::config::FidelityTier::Exact;
        let mut mirror = AdraEngine::new(&mirror_cfg);
        assert!(masked.masked_active());
        assert!(!mirror.masked_active());
        let mut rng = Rng::new(23);
        for round in 0..24 {
            let (a, b) = (rng.below(256), rng.below(256));
            let row = (round % 6) * 2;
            for e in [&mut masked, &mut mirror] {
                e.execute(&CimOp::Write { addr: WordAddr { row, word: 1 }, value: a }).unwrap();
                e.execute(&CimOp::Write { addr: WordAddr { row: row + 1, word: 1 }, value: b })
                    .unwrap();
            }
            let ops = [
                CimOp::Read2 { row_a: row, row_b: row + 1, word: 1 },
                CimOp::Add { row_a: row, row_b: row + 1, word: 1 },
                CimOp::Sub { row_a: row, row_b: row + 1, word: 1 },
                CimOp::Compare { row_a: row, row_b: row + 1, word: 1 },
                CimOp::Bool { f: BoolFn::AndNot, row_a: row, row_b: row + 1, word: 1 },
                CimOp::Read(WordAddr { row, word: 1 }),
            ];
            for op in &ops {
                let got = masked.execute(op).unwrap();
                let want = mirror.execute(op).unwrap();
                assert_eq!(got.value, want.value, "{op:?} a={a:#x} b={b:#x}");
                assert_eq!(got.cost, want.cost, "{op:?}");
            }
        }
        let s = masked.array().stats();
        assert!(s.masked_activations > 0, "{s:?}");
        assert!(s.det_cols > 0, "{s:?}");
        assert!(s.det_col_fraction() > 0.8, "{s:?}");
        assert_eq!(s.xval_mismatches, 0, "{s:?}");
        assert_eq!(mirror.array().stats().masked_activations, 0);
    }

    #[test]
    fn masked_xval_samples_against_planes() {
        let cfg = varied_cfg(crate::config::MaskPolicy::Construction);
        let mut e = AdraEngine::new(&cfg);
        assert!(e.masked_active());
        setup(&mut e, 0xA5, 0x3C);
        let n = 3 * AdraEngine::XVAL_PERIOD;
        for _ in 0..n {
            e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let s = e.array().stats();
        assert!(s.xval_checks >= 3, "sampling must run under variation: {s:?}");
        assert_eq!(s.xval_mismatches, 0, "planes must agree with the analog rerun: {s:?}");
    }
}
