//! The ADRA CiM engine: asymmetric dual-row activation + three-SA sensing
//! + the Fig. 3(d) compute modules, over either sensing family.
//!
//! Activations run through a **tiered kernel** (`SimConfig::tier`, see
//! DESIGN.md §9): when decisions are provably deterministic the digital
//! tier serves dual-row ops as packed bitwise ops over the array's
//! shadow plane (64 columns per instruction, sampled cross-validation
//! against the analog pipeline); the analog tiers (`Lut`/`Exact`) run a
//! zero-allocation pipeline through reusable engine scratch.  All tiers
//! report identical values and modeled costs.
//!
//! The analog senseline evaluation is pluggable (`AnalogBackend`): the
//! behavioral device model serves the fast path; the PJRT runtime backend
//! (`runtime::PjrtBackend`) executes the AOT JAX/Pallas artifacts for
//! analog ground truth.  Both produce identical digital decisions — that
//! equivalence is asserted by the cross-validation integration test.

use crate::array::FefetArray;
use crate::config::{SensingScheme, SimConfig};
use crate::energy::EnergyModel;
use crate::logic::{and_tree_equal, ripple_add_sub, CompareResult};
use crate::sensing::{CurrentRefs, CurrentSenseBank, SenseOut, VoltageRefs, VoltageSenseBank};

use super::ops::{BoolFn, CimOp, CimResult, CimValue, Engine, EngineError, WordAddr};

/// Pluggable analog evaluation of one dual-row activation.
pub trait AnalogBackend: Send {
    /// DC senseline currents per column (current sensing).
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64>;

    /// Final RBL voltages per column after the discharge window
    /// (voltage sensing), for total bitline capacitance `c_rbl`.
    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64>;

    /// `dc_isl` into a caller-owned buffer (cleared first).  Backends on
    /// the hot path override this to avoid the per-activation allocation;
    /// the default delegates to the allocating variant.
    #[allow(clippy::too_many_arguments)]
    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        *out = self.dc_isl(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2);
    }

    /// `transient_vfinal` into a caller-owned buffer (cleared first).
    #[allow(clippy::too_many_arguments)]
    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        *out = self.transient_vfinal(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl);
    }

    fn name(&self) -> &'static str;
}

/// Behavioral backend: the Rust device model (fast path).
///
/// §Perf: evaluations go through the separable `CellLut` tables
/// (`device::lut`), which match the exact model to < 1e-5 relative — see
/// EXPERIMENTS.md §Perf for the before/after and `lut::tests` for the
/// accuracy pins.  The exact closed-form path remains available in
/// `device::{senseline_current, rbl_transient}` for validation.
pub struct BehavioralBackend {
    params: crate::config::DeviceParams,
    lut: crate::device::CellLut,
    /// lazily-built O(1) transient table, keyed by the c_rbl it was built
    /// for (engines pass a fixed c_rbl, so this builds exactly once).
    transient: Option<crate::device::lut::TransientTable>,
}

impl BehavioralBackend {
    pub fn new(params: &crate::config::DeviceParams) -> Self {
        Self {
            params: params.clone(),
            lut: crate::device::CellLut::new(params),
            transient: None,
        }
    }

    /// Build (or rebuild) the transient table for this `c_rbl`; a no-op
    /// when the cached table is already current.
    fn ensure_transient(&mut self, c_rbl: f64) {
        let stale = match &self.transient {
            Some(t) => t.c_rbl != c_rbl || t.v0 != self.params.v_read,
            None => true,
        };
        if stale {
            self.transient = Some(crate::device::lut::TransientTable::new(
                &self.params,
                &self.lut,
                self.params.v_read,
                c_rbl,
            ));
        }
    }
}

impl AnalogBackend for BehavioralBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.dc_isl_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, &mut out);
        out
    }

    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        let s = self.lut.s(self.params.v_read);
        out.clear();
        for i in 0..pol_a.len() {
            let fa = self.lut.f(self.lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64));
            let fb = self.lut.f(self.lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64));
            out.push((fa + fb) * s);
        }
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.transient_vfinal_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl, &mut out);
        out
    }

    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        self.ensure_transient(c_rbl);
        let table = self.transient.as_ref().expect("transient table built");
        let lut = &self.lut;
        out.clear();
        for i in 0..pol_a.len() {
            let f = lut.f(lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64))
                + lut.f(lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64));
            out.push(table.v_final(f));
        }
    }

    fn name(&self) -> &'static str {
        "behavioral"
    }
}

/// Exact-model backend (`FidelityTier::Exact`): the closed-form device
/// equations, no LUT approximation.  Slow; used for validation and as the
/// reference the faster tiers are pinned against.
pub struct ExactBackend {
    params: crate::config::DeviceParams,
}

impl ExactBackend {
    pub fn new(params: &crate::config::DeviceParams) -> Self {
        Self { params: params.clone() }
    }
}

impl AnalogBackend for ExactBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.dc_isl_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, &mut out);
        out
    }

    fn dc_isl_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        out: &mut Vec<f64>,
    ) {
        let p = &self.params;
        out.clear();
        for i in 0..pol_a.len() {
            out.push(crate::device::senseline_current(
                p,
                pol_a[i] as f64,
                pol_b[i] as f64,
                vg1,
                vg2,
                p.v_read,
                dvt_a[i] as f64,
                dvt_b[i] as f64,
            ));
        }
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let mut out = Vec::new();
        self.transient_vfinal_into(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl, &mut out);
        out
    }

    fn transient_vfinal_into(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
        out: &mut Vec<f64>,
    ) {
        let p = &self.params;
        out.clear();
        for i in 0..pol_a.len() {
            out.push(
                crate::device::rbl_transient(
                    p,
                    pol_a[i] as f64,
                    pol_b[i] as f64,
                    vg1,
                    vg2,
                    p.v_read,
                    c_rbl,
                    dvt_a[i] as f64,
                    dvt_b[i] as f64,
                )
                .v_final,
            );
        }
    }

    fn name(&self) -> &'static str {
        "exact"
    }
}

/// Reusable per-engine buffers: the analog pipeline runs allocation-free
/// after warmup (`planes_into` -> `*_into` backend eval -> `sense_into`).
#[derive(Default)]
struct EngineScratch {
    pol_a: Vec<f32>,
    pol_b: Vec<f32>,
    dvt_a: Vec<f32>,
    dvt_b: Vec<f32>,
    /// Backend output: I_SL (current sensing) or V_final (voltage).
    analog: Vec<f64>,
    /// Per-column sense decisions of the latest activation.
    sense: Vec<SenseOut>,
}

/// What one dual-row activation produced: packed operand words straight
/// from the digital shadow plane, or per-column sense outputs left in the
/// engine scratch by an analog tier.
enum Sensed {
    Digital(u64, u64),
    Analog,
}

/// The full ADRA engine.
pub struct AdraEngine {
    cfg: SimConfig,
    array: FefetArray,
    energy: EnergyModel,
    cur_bank: CurrentSenseBank,
    volt_bank: VoltageSenseBank,
    backend: Box<dyn AnalogBackend>,
    /// fast separable device tables for the single-row read path (§Perf).
    lut: crate::device::CellLut,
    scratch: EngineScratch,
    /// Digital tier engaged: `cfg.tier == Digital`, `vt_sigma == 0`, and
    /// the one-time margin check against the analog references passed.
    digital_ok: bool,
    /// Digital activations since construction (drives xval sampling).
    xval_tick: u64,
}

impl AdraEngine {
    /// Every `XVAL_PERIOD`-th digital activation re-runs the analog
    /// pipeline and compares decisions (`ArrayStats::xval_*`).
    pub const XVAL_PERIOD: u64 = 64;

    /// Engine with the analog backend selected by `cfg.tier`
    /// (`Digital`/`Lut` -> LUT behavioral model, `Exact` -> closed form).
    /// The digital fast path engages only here, after calibration proves
    /// decisions deterministic.
    pub fn new(cfg: &SimConfig) -> Self {
        let backend: Box<dyn AnalogBackend> = match cfg.tier {
            crate::config::FidelityTier::Exact => Box::new(ExactBackend::new(&cfg.device)),
            _ => Box::new(BehavioralBackend::new(&cfg.device)),
        };
        let mut e = Self::with_backend(cfg, backend);
        if cfg.tier == crate::config::FidelityTier::Digital && cfg.vt_sigma == 0.0 {
            e.digital_ok = e.margin_check();
        }
        e
    }

    /// Engine with a custom analog backend (e.g. the PJRT artifact path).
    /// An explicit backend always runs the analog pipeline — the caller
    /// asked for that backend to be exercised, so the digital shortcut
    /// stays off regardless of `cfg.tier`.
    pub fn with_backend(cfg: &SimConfig, backend: Box<dyn AnalogBackend>) -> Self {
        let p = &cfg.device;
        let c_rbl = cfg.c_rbl();
        Self {
            cfg: cfg.clone(),
            array: FefetArray::new(cfg),
            energy: EnergyModel::new(cfg),
            cur_bank: CurrentSenseBank::new(CurrentRefs::derive(p, p.v_gread1, p.v_gread2)),
            volt_bank: VoltageSenseBank::new(VoltageRefs::derive(
                p, p.v_gread1, p.v_gread2, c_rbl,
            )),
            backend,
            lut: crate::device::CellLut::new(p),
            scratch: EngineScratch::default(),
            digital_ok: false,
            xval_tick: 0,
        }
    }

    /// The configured fidelity tier.
    pub fn tier(&self) -> crate::config::FidelityTier {
        self.cfg.tier
    }

    /// Is the bit-packed digital fast path serving activations?
    pub fn digital_active(&self) -> bool {
        self.digital_ok
    }

    /// One-time calibration: push the four (A,B) corner vectors (and the
    /// single-read levels) through THIS engine's analog backend and sense
    /// banks, and require every decision to decode correctly.  With
    /// `vt_sigma == 0` the analog pipeline is a pure function of the
    /// stored bits, so passing here proves the packed digital decisions
    /// are identical to the analog tier's.
    fn margin_check(&mut self) -> bool {
        let p = self.cfg.device.clone();
        let c_rbl = self.cfg.c_rbl();
        let mut ok = true;
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            let pol_a = [p.pol_of_bit(a) as f32];
            let pol_b = [p.pol_of_bit(b) as f32];
            let z = [0.0f32];
            let out = match self.cfg.scheme {
                SensingScheme::Current => {
                    self.backend.dc_isl_into(
                        &pol_a, &pol_b, &z, &z, p.v_gread1, p.v_gread2,
                        &mut self.scratch.analog,
                    );
                    self.cur_bank.sense(self.scratch.analog[0])
                }
                SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                    self.backend.transient_vfinal_into(
                        &pol_a, &pol_b, &z, &z, p.v_gread1, p.v_gread2, c_rbl,
                        &mut self.scratch.analog,
                    );
                    self.volt_bank.sense(self.scratch.analog[0])
                }
            };
            ok &= out.or == (a || b) && out.b == b && out.and == (a && b) && out.a() == a;
        }
        // the single-row read decision must be deterministic too
        let s = self.lut.s(p.v_read);
        let i_lrs = self.lut.f(self.lut.u_of(p.v_gread2, p.pol_of_bit(true), 0.0)) * s;
        let i_hrs = self.lut.f(self.lut.u_of(p.v_gread2, p.pol_of_bit(false), 0.0)) * s;
        ok && self.cur_bank.sense_read(i_lrs) && !self.cur_bank.sense_read(i_hrs)
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn array(&self) -> &FefetArray {
        &self.array
    }

    pub fn array_mut(&mut self) -> &mut FefetArray {
        &mut self.array
    }

    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    fn check_word(&self, row: usize, word: usize) -> Result<(), EngineError> {
        if row >= self.cfg.rows || word >= self.cfg.words_per_row() {
            return Err(EngineError::OutOfRange(format!(
                "row {row} word {word} (array {}x{} words/row {})",
                self.cfg.rows,
                self.cfg.cols,
                self.cfg.words_per_row()
            )));
        }
        Ok(())
    }

    fn word_cols(&self, word: usize) -> (usize, usize) {
        let lo = word * self.cfg.word_bits;
        (lo, lo + self.cfg.word_bits)
    }

    /// Validate one dual-row activation's addressing.
    fn check_pair(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> Result<(), EngineError> {
        if row_a == row_b {
            return Err(EngineError::Unsupported(
                "dual-row activation requires two distinct rows".into(),
            ));
        }
        if row_a >= self.cfg.rows
            || row_b >= self.cfg.rows
            || col_lo >= col_hi
            || col_hi > self.cfg.cols
        {
            return Err(EngineError::OutOfRange(format!(
                "rows {row_a}/{row_b} cols {col_lo}..{col_hi} (array {}x{})",
                self.cfg.rows, self.cfg.cols
            )));
        }
        Ok(())
    }

    /// Run the zero-allocation analog pipeline for `[lo, hi)` of the row
    /// pair: planes -> backend eval -> sense bank, all into the engine
    /// scratch.  Purely computational — no stats.
    fn fill_sense_analog(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        let vg1 = self.cfg.device.v_gread1;
        let vg2 = self.cfg.device.v_gread2;
        self.array.planes_into(
            row_a,
            row_b,
            lo,
            hi,
            &mut self.scratch.pol_a,
            &mut self.scratch.pol_b,
            &mut self.scratch.dvt_a,
            &mut self.scratch.dvt_b,
        );
        match self.cfg.scheme {
            SensingScheme::Current => {
                self.backend.dc_isl_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    &mut self.scratch.analog,
                );
                self.cur_bank.sense_into(&self.scratch.analog, &mut self.scratch.sense);
            }
            SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                let c_rbl = self.cfg.c_rbl();
                self.backend.transient_vfinal_into(
                    &self.scratch.pol_a,
                    &self.scratch.pol_b,
                    &self.scratch.dvt_a,
                    &self.scratch.dvt_b,
                    vg1,
                    vg2,
                    c_rbl,
                    &mut self.scratch.analog,
                );
                self.volt_bank.sense_into(&self.scratch.analog, &mut self.scratch.sense);
            }
        }
    }

    /// Build the sense vector for `[lo, hi)` from the bit-packed shadow
    /// plane — `or = a | b`, `and = a & b`, 64 columns per instruction.
    fn fill_sense_digital(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        self.scratch.sense.clear();
        let mut c = lo;
        while c < hi {
            let w = (hi - c).min(64);
            let a = self.array.packed_window(row_a, c, c + w);
            let b = self.array.packed_window(row_b, c, c + w);
            let or = a | b;
            let and = a & b;
            for i in 0..w {
                self.scratch.sense.push(SenseOut {
                    or: (or >> i) & 1 == 1,
                    b: (b >> i) & 1 == 1,
                    and: (and >> i) & 1 == 1,
                });
            }
            c += w;
        }
    }

    /// Sanity on the analog decode: an OR=0/AND=1 column means the
    /// margins collapsed.
    fn check_margins(&self) -> Result<(), EngineError> {
        for (i, o) in self.scratch.sense.iter().enumerate() {
            if o.and && !o.or {
                return Err(EngineError::SenseFailure(format!(
                    "column {i}: AND asserted without OR — margin collapse"
                )));
            }
        }
        Ok(())
    }

    /// Sampled cross-validation of the digital tier: every
    /// `XVAL_PERIOD`-th digital activation re-runs the analog pipeline
    /// over the same window and compares every column's (OR, B, AND)
    /// decision against the shadow plane.  Counts in `ArrayStats`.
    fn maybe_cross_validate(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        self.xval_tick += 1;
        if self.xval_tick % Self::XVAL_PERIOD != 0 {
            return;
        }
        self.fill_sense_analog(row_a, row_b, lo, hi);
        let mut mismatch = false;
        for (i, c) in (lo..hi).enumerate() {
            let a = self.array.packed_window(row_a, c, c + 1) & 1 == 1;
            let b = self.array.packed_window(row_b, c, c + 1) & 1 == 1;
            let o = self.scratch.sense[i];
            if o.or != (a || b) || o.b != b || o.and != (a && b) {
                mismatch = true;
            }
        }
        let stats = self.array.stats_mut();
        stats.xval_checks += 1;
        if mismatch {
            stats.xval_mismatches += 1;
        }
    }

    /// Shared digital-path bookkeeping: tier counter + sampled
    /// cross-validation.  Every digital activation goes through here.
    fn digital_preamble(&mut self, row_a: usize, row_b: usize, lo: usize, hi: usize) {
        self.array.stats_mut().digital_activations += 1;
        self.maybe_cross_validate(row_a, row_b, lo, hi);
    }

    /// Shared analog-path activation: zero-allocation pipeline into
    /// scratch + margin sanity.  Every analog activation goes through
    /// here.
    fn analog_activate(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(), EngineError> {
        self.fill_sense_analog(row_a, row_b, lo, hi);
        self.check_margins()
    }

    /// One dual-row activation over `[lo, hi)`: records stats, leaves the
    /// per-column sense decisions in `scratch.sense` (either tier).
    fn sense_cols(
        &mut self,
        row_a: usize,
        row_b: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(), EngineError> {
        self.note_dual_access(lo, hi);
        if self.digital_ok {
            self.digital_preamble(row_a, row_b, lo, hi);
            self.fill_sense_digital(row_a, row_b, lo, hi);
            Ok(())
        } else {
            self.analog_activate(row_a, row_b, lo, hi)
        }
    }

    /// The scalar-op activation: the digital tier returns the packed
    /// operand words directly (no per-column materialization at all); the
    /// analog tiers leave sense outputs in scratch.
    fn activate(&mut self, row_a: usize, row_b: usize, word: usize) -> Result<Sensed, EngineError> {
        let (lo, hi) = self.word_cols(word);
        self.check_pair(row_a, row_b, lo, hi)?;
        self.note_dual_access(lo, hi);
        if self.digital_ok {
            self.digital_preamble(row_a, row_b, lo, hi);
            let a = self.array.packed_window(row_a, lo, hi);
            let b = self.array.packed_window(row_b, lo, hi);
            Ok(Sensed::Digital(a, b))
        } else {
            self.analog_activate(row_a, row_b, lo, hi)?;
            Ok(Sensed::Analog)
        }
    }

    fn note_dual_access(&mut self, lo: usize, hi: usize) {
        // FefetArray::planes_into doesn't mutate stats; account the
        // activation here so every tier/backend is counted identically.
        let cols = self.array.cols();
        let s = self.array_stats_mut();
        s.dual_activations += 1;
        s.half_selected_cols += (cols - (hi - lo)) as u64;
    }

    fn array_stats_mut(&mut self) -> &mut crate::array::ArrayStats {
        // small helper: FefetArray exposes stats by value; keep a shadow
        // counter through reset/read (see ArrayStats usage in tests).
        // Implemented via interior access on the array.
        self.array.stats_mut()
    }

    /// Public access to one dual-row activation + sensing over a word
    /// window.  Counts one array activation.  Returns an owned vector
    /// (one allocation per call) — hot paths should prefer
    /// `activate_cols`, which returns a borrow of the engine scratch.
    pub fn activate_word(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Vec<SenseOut>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        let (lo, hi) = self.word_cols(word);
        self.check_pair(row_a, row_b, lo, hi)?;
        self.sense_cols(row_a, row_b, lo, hi)?;
        Ok(self.scratch.sense.clone())
    }

    /// One dual-row activation sensing an arbitrary column window (the
    /// wordlines span the whole row anyway): ONE recorded activation,
    /// `cols - (col_hi - col_lo)` half-selected columns, sense outputs
    /// for every addressed column.  Returns a borrow of the engine's
    /// sense scratch — copy out before the next activation.
    pub fn activate_cols(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> Result<&[SenseOut], EngineError> {
        self.check_pair(row_a, row_b, col_lo, col_hi)?;
        self.sense_cols(row_a, row_b, col_lo, col_hi)?;
        Ok(&self.scratch.sense)
    }

    /// One dual-row activation sensing EVERY column of the row pair —
    /// the single-call row API the vector engine builds on.  Exactly one
    /// dual activation and zero half-selected columns are recorded.
    pub fn activate_row(
        &mut self,
        row_a: usize,
        row_b: usize,
    ) -> Result<&[SenseOut], EngineError> {
        let cols = self.cfg.cols;
        self.activate_cols(row_a, row_b, 0, cols)
    }

    /// Assemble words from per-bit sense outputs.
    fn words_from(outs: &[SenseOut]) -> (u64, u64) {
        let mut a = 0u64;
        let mut b = 0u64;
        for (i, o) in outs.iter().enumerate() {
            if o.a() {
                a |= 1 << i;
            }
            if o.b {
                b |= 1 << i;
            }
        }
        (a, b)
    }

    fn bool_from(f: BoolFn, outs: &[SenseOut]) -> u64 {
        let mut v = 0u64;
        for (i, o) in outs.iter().enumerate() {
            let bit = match f {
                BoolFn::And => o.and,
                BoolFn::Or => o.or,
                BoolFn::Nand => !o.and,
                BoolFn::Nor => !o.or,
                BoolFn::Xor => o.xor(),
                BoolFn::Xnor => !o.xor(),
                BoolFn::AndNot => o.a() && !o.b,
                BoolFn::OrNot => o.a() || !o.b,
            };
            if bit {
                v |= 1 << i;
            }
        }
        v
    }

    /// Standard single-row read through the sensing path (LUT-fast; the
    /// digital tier serves it straight from the shadow plane — the read
    /// decode was proven deterministic by the margin check).
    fn read_word_sensed(&mut self, addr: WordAddr) -> Result<u64, EngineError> {
        self.check_word(addr.row, addr.word)?;
        let (lo, hi) = self.word_cols(addr.word);
        self.array.stats_mut().reads += 1;
        if self.digital_ok {
            return Ok(self.array.packed_window(addr.row, lo, hi));
        }
        let vg = self.cfg.device.v_gread2;
        let s = self.lut.s(self.cfg.device.v_read);
        let mut v = 0u64;
        for (i, c) in (lo..hi).enumerate() {
            let i_cell = self.lut.f(self.lut.u_of(
                vg,
                self.array.pol(addr.row, c),
                self.array.dvt(addr.row, c),
            )) * s;
            if self.cur_bank.sense_read(i_cell) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    /// All-ones mask of a word's width.
    #[inline]
    fn word_mask(bits: usize) -> u64 {
        if bits >= 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        }
    }

    /// Two's-complement interpretation of an n-bit word.
    #[inline]
    fn signed_of(v: u64, bits: usize) -> i128 {
        let sign = 1u64 << (bits - 1);
        if v & sign != 0 {
            v as i128 - (1i128 << bits)
        } else {
            v as i128
        }
    }

    /// Evaluate a dual-row op from the packed operand words — the digital
    /// tier's op derivation, shared by `execute` and the fused datapath.
    /// Returns `None` for ops that are not dual-row.
    pub(crate) fn digital_value(op: &CimOp, a: u64, b: u64, word_bits: usize) -> Option<CimValue> {
        Some(match *op {
            CimOp::Read2 { .. } => CimValue::Pair(a, b),
            CimOp::Bool { f, .. } => CimValue::Word(f.apply(a, b, Self::word_mask(word_bits))),
            // the packed sum equals the ripple chain's (n+1)-bit unsigned
            // result exactly; sub/compare match its signed semantics
            CimOp::Add { .. } => CimValue::Sum(a as u128 + b as u128),
            CimOp::Sub { .. } => {
                CimValue::Diff(Self::signed_of(a, word_bits) - Self::signed_of(b, word_bits))
            }
            CimOp::Compare { .. } => CimValue::Ordering(if a == b {
                CompareResult::Equal
            } else if Self::signed_of(a, word_bits) < Self::signed_of(b, word_bits) {
                CompareResult::Less
            } else {
                CompareResult::Greater
            }),
            CimOp::Read(_) | CimOp::Write { .. } => return None,
        })
    }

    /// Evaluate a dual-row op from per-column sense outputs — the analog
    /// tiers' op derivation, shared by `execute` and the fused datapath.
    pub(crate) fn analog_value(op: &CimOp, outs: &[SenseOut]) -> CimValue {
        match *op {
            CimOp::Read2 { .. } => {
                let (a, b) = Self::words_from(outs);
                CimValue::Pair(a, b)
            }
            CimOp::Bool { f, .. } => CimValue::Word(Self::bool_from(f, outs)),
            CimOp::Add { .. } => CimValue::Sum(ripple_add_sub(outs, false).as_unsigned()),
            CimOp::Sub { .. } => CimValue::Diff(ripple_add_sub(outs, true).as_signed()),
            CimOp::Compare { .. } => {
                let diff = ripple_add_sub(outs, true);
                CimValue::Ordering(if and_tree_equal(&diff.bits) {
                    CompareResult::Equal
                } else if diff.sign() {
                    CompareResult::Less
                } else {
                    CompareResult::Greater
                })
            }
            CimOp::Read(_) | CimOp::Write { .. } => {
                unreachable!("only dual-row ops go through sensing")
            }
        }
    }

    /// One dual-row activation for the fused datapath: the digital tier
    /// returns the packed operand words (derive followers with
    /// `digital_value` — no per-column work at all); the analog tiers
    /// return `None` with the sense outputs left in the engine scratch
    /// (read them back with `last_sense`).
    pub(crate) fn activate_packed(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Option<(u64, u64)>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        match self.activate(row_a, row_b, word)? {
            Sensed::Digital(a, b) => Ok(Some((a, b))),
            Sensed::Analog => Ok(None),
        }
    }

    /// Sense outputs of the latest analog activation (valid until the
    /// next activation; the fused path reads this right after
    /// `activate_packed` returns `Ok(None)`).
    pub(crate) fn last_sense(&self) -> &[SenseOut] {
        &self.scratch.sense
    }
}

impl Engine for AdraEngine {
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError> {
        match *op {
            CimOp::Write { addr, value } => {
                self.check_word(addr.row, addr.word)?;
                self.array.write_word(addr.row, addr.word, value);
                Ok(CimResult { value: CimValue::None, cost: self.energy.write_cost() })
            }
            CimOp::Read(addr) => {
                let v = self.read_word_sensed(addr)?;
                Ok(CimResult { value: CimValue::Word(v), cost: self.energy.read_cost() })
            }
            CimOp::Read2 { row_a, row_b, word }
            | CimOp::Bool { row_a, row_b, word, .. }
            | CimOp::Add { row_a, row_b, word }
            | CimOp::Sub { row_a, row_b, word }
            | CimOp::Compare { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let wb = self.cfg.word_bits;
                let value = match self.activate(row_a, row_b, word)? {
                    Sensed::Digital(a, b) => {
                        Self::digital_value(op, a, b, wb).expect("dual-row op")
                    }
                    Sensed::Analog => Self::analog_value(op, &self.scratch.sense),
                };
                Ok(CimResult { value, cost: self.energy.cim_cost() })
            }
        }
    }

    /// ADRA has a native fused datapath: dual ops over the same operand
    /// pair share one asymmetric activation (`coordinator::fuse`).
    fn execute_fused(&mut self, ops: &[CimOp]) -> Option<Vec<Result<CimResult, EngineError>>> {
        Some(crate::coordinator::fuse::execute_fused(self, ops))
    }

    fn array_stats(&self) -> Option<crate::array::ArrayStats> {
        Some(self.array.stats())
    }

    fn name(&self) -> &'static str {
        "adra"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine(scheme: SensingScheme) -> AdraEngine {
        let mut cfg = SimConfig::square(256, scheme);
        cfg.word_bits = 8;
        AdraEngine::new(&cfg)
    }

    fn setup(e: &mut AdraEngine, a: u64, b: u64) {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    }

    #[test]
    fn read2_recovers_both_words_single_access() {
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            setup(&mut e, 0xA5, 0x3C);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(0xA5, 0x3C), "{scheme:?}");
        }
    }

    #[test]
    fn all_boolean_functions_correct() {
        let mut rng = Rng::new(11);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..8 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                for f in BoolFn::ALL {
                    let r = e
                        .execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 })
                        .unwrap();
                    assert_eq!(
                        r.value,
                        CimValue::Word(f.apply(a, b, 0xFF)),
                        "{scheme:?} {f:?} a={a:#x} b={b:#x}"
                    );
                }
            }
        }
    }

    #[test]
    fn add_and_sub_match_integers() {
        let mut rng = Rng::new(13);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..16 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                let add = e.execute(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }).unwrap();
                assert_eq!(add.value, CimValue::Sum((a + b) as u128));
                let sub = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
                let sa = (a as i128) - if a >= 128 { 256 } else { 0 };
                let sb = (b as i128) - if b >= 128 { 256 } else { 0 };
                assert_eq!(sub.value, CimValue::Diff(sa - sb), "a={a} b={b} {scheme:?}");
            }
        }
    }

    #[test]
    fn compare_matches_signed_order() {
        let mut e = engine(SensingScheme::Current);
        for (a, b, expect) in [
            (5u64, 9u64, CompareResult::Less),
            (9, 5, CompareResult::Greater),
            (7, 7, CompareResult::Equal),
            (0x80, 0x7F, CompareResult::Less), // -128 < 127
        ] {
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Ordering(expect), "a={a:#x} b={b:#x}");
        }
    }

    #[test]
    fn single_access_for_cim_ops() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 3, 5);
        e.array_mut().reset_stats();
        e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1, "subtraction must be ONE access");
        assert_eq!(s.reads, 0);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut e = engine(SensingScheme::Current);
        assert!(matches!(
            e.execute(&CimOp::Read(WordAddr { row: 9999, word: 0 })),
            Err(EngineError::OutOfRange(_))
        ));
        assert!(matches!(
            e.execute(&CimOp::Sub { row_a: 0, row_b: 0, word: 0 }),
            Err(EngineError::Unsupported(_))
        ));
    }

    #[test]
    fn standard_read_via_sense_path() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0xC3, 0);
        let r = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r.value, CimValue::Word(0xC3));
    }

    #[test]
    fn costs_attached_and_ordered() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 1, 2);
        let read = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        let cim = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert!(cim.cost.energy.total() > read.cost.energy.total());
        assert!(cim.cost.latency > read.cost.latency);
        // but FAR less than two reads (that's the point of the paper)
        assert!(cim.cost.energy.total() < 2.0 * read.cost.energy.total());
    }

    #[test]
    fn digital_tier_engages_on_default_config() {
        let e = engine(SensingScheme::Current);
        assert_eq!(e.tier(), crate::config::FidelityTier::Digital);
        assert!(e.digital_active(), "margin check must pass at the paper bias");
    }

    #[test]
    fn digital_activations_counted_as_subset() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0x5A, 0x0F);
        e.array_mut().reset_stats();
        for _ in 0..5 {
            e.execute(&CimOp::Bool { f: BoolFn::Or, row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 5);
        assert_eq!(s.digital_activations, 5, "digital tier must serve all of them");
        assert_eq!(s.xval_mismatches, 0);
    }

    #[test]
    fn lut_tier_serves_no_digital_activations() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.tier = crate::config::FidelityTier::Lut;
        let mut e = AdraEngine::new(&cfg);
        assert!(!e.digital_active());
        setup(&mut e, 0x5A, 0x0F);
        let r = e.execute(&CimOp::Bool { f: BoolFn::Xor, row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Word(0x55));
        assert_eq!(e.array().stats().digital_activations, 0);
    }

    #[test]
    fn explicit_backend_keeps_analog_pipeline() {
        let cfg = {
            let mut c = SimConfig::square(64, SensingScheme::Current);
            c.word_bits = 8;
            c
        };
        let mut e =
            AdraEngine::with_backend(&cfg, Box::new(BehavioralBackend::new(&cfg.device)));
        assert!(!e.digital_active(), "explicit backends must be exercised");
        setup(&mut e, 9, 4);
        let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(5));
        assert_eq!(e.array().stats().digital_activations, 0);
    }

    #[test]
    fn cross_validation_samples_and_agrees() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0xA5, 0x3C);
        let n = 3 * AdraEngine::XVAL_PERIOD;
        for _ in 0..n {
            e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        }
        let s = e.array().stats();
        assert!(s.xval_checks >= 3, "sampling must have triggered: {s:?}");
        assert_eq!(s.xval_mismatches, 0, "digital and analog tiers must agree");
    }

    #[test]
    fn activate_row_records_one_activation_no_half_selects() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 1, 2);
        e.array_mut().reset_stats();
        let cols = e.cfg().cols;
        let outs = e.activate_row(0, 1).unwrap();
        assert_eq!(outs.len(), cols);
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1);
        assert_eq!(s.half_selected_cols, 0, "full row: nothing is half-selected");
    }

    #[test]
    fn activate_cols_counts_half_selects_once() {
        let mut e = engine(SensingScheme::Current);
        e.array_mut().reset_stats();
        let cols = e.cfg().cols;
        let outs = e.activate_cols(0, 1, 8, 40).unwrap();
        assert_eq!(outs.len(), 32);
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1);
        assert_eq!(s.half_selected_cols, (cols - 32) as u64);
        assert!(matches!(e.activate_cols(0, 0, 0, 8), Err(EngineError::Unsupported(_))));
        assert!(matches!(e.activate_cols(0, 1, 8, 8), Err(EngineError::OutOfRange(_))));
    }

    #[test]
    fn works_with_variation() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.vt_sigma = 0.02; // 20 mV sigma
        let mut e = AdraEngine::new(&cfg);
        let mut rng = Rng::new(17);
        for _ in 0..16 {
            let (a, b) = (rng.below(256), rng.below(256));
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(a, b), "variation broke sensing");
        }
    }
}
