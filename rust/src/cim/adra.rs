//! The ADRA CiM engine: asymmetric dual-row activation + three-SA sensing
//! + the Fig. 3(d) compute modules, over either sensing family.
//!
//! The analog senseline evaluation is pluggable (`AnalogBackend`): the
//! behavioral device model serves the fast path; the PJRT runtime backend
//! (`runtime::PjrtBackend`) executes the AOT JAX/Pallas artifacts for
//! analog ground truth.  Both produce identical digital decisions — that
//! equivalence is asserted by the cross-validation integration test.

use crate::array::FefetArray;
use crate::config::{SensingScheme, SimConfig};
use crate::energy::EnergyModel;
use crate::logic::{and_tree_equal, ripple_add_sub, CompareResult};
use crate::sensing::{CurrentRefs, CurrentSenseBank, SenseOut, VoltageRefs, VoltageSenseBank};

use super::ops::{BoolFn, CimOp, CimResult, CimValue, Engine, EngineError, WordAddr};

/// Pluggable analog evaluation of one dual-row activation.
pub trait AnalogBackend: Send {
    /// DC senseline currents per column (current sensing).
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64>;

    /// Final RBL voltages per column after the discharge window
    /// (voltage sensing), for total bitline capacitance `c_rbl`.
    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64>;

    fn name(&self) -> &'static str;
}

/// Behavioral backend: the Rust device model (fast path).
///
/// §Perf: evaluations go through the separable `CellLut` tables
/// (`device::lut`), which match the exact model to < 1e-5 relative — see
/// EXPERIMENTS.md §Perf for the before/after and `lut::tests` for the
/// accuracy pins.  The exact closed-form path remains available in
/// `device::{senseline_current, rbl_transient}` for validation.
pub struct BehavioralBackend {
    params: crate::config::DeviceParams,
    lut: crate::device::CellLut,
    /// lazily-built O(1) transient table, keyed by the c_rbl it was built
    /// for (engines pass a fixed c_rbl, so this builds exactly once).
    transient: Option<crate::device::lut::TransientTable>,
}

impl BehavioralBackend {
    pub fn new(params: &crate::config::DeviceParams) -> Self {
        Self {
            params: params.clone(),
            lut: crate::device::CellLut::new(params),
            transient: None,
        }
    }

    fn transient_table(&mut self, c_rbl: f64) -> &crate::device::lut::TransientTable {
        let stale = match &self.transient {
            Some(t) => t.c_rbl != c_rbl || t.v0 != self.params.v_read,
            None => true,
        };
        if stale {
            self.transient = Some(crate::device::lut::TransientTable::new(
                &self.params,
                &self.lut,
                self.params.v_read,
                c_rbl,
            ));
        }
        self.transient.as_ref().unwrap()
    }
}

impl AnalogBackend for BehavioralBackend {
    fn dc_isl(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        let s = self.lut.s(self.params.v_read);
        (0..pol_a.len())
            .map(|i| {
                let fa = self.lut.f(self.lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64));
                let fb = self.lut.f(self.lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64));
                (fa + fb) * s
            })
            .collect()
    }

    fn transient_vfinal(
        &mut self,
        pol_a: &[f32],
        pol_b: &[f32],
        dvt_a: &[f32],
        dvt_b: &[f32],
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<f64> {
        let f_sums: Vec<f64> = (0..pol_a.len())
            .map(|i| {
                self.lut.f(self.lut.u_of(vg1, pol_a[i] as f64, dvt_a[i] as f64))
                    + self.lut.f(self.lut.u_of(vg2, pol_b[i] as f64, dvt_b[i] as f64))
            })
            .collect();
        let table = self.transient_table(c_rbl);
        f_sums.into_iter().map(|f| table.v_final(f)).collect()
    }

    fn name(&self) -> &'static str {
        "behavioral"
    }
}

/// The full ADRA engine.
pub struct AdraEngine {
    cfg: SimConfig,
    array: FefetArray,
    energy: EnergyModel,
    cur_bank: CurrentSenseBank,
    volt_bank: VoltageSenseBank,
    backend: Box<dyn AnalogBackend>,
    /// fast separable device tables for the single-row read path (§Perf).
    lut: crate::device::CellLut,
}

impl AdraEngine {
    /// Engine with the behavioral analog backend.
    pub fn new(cfg: &SimConfig) -> Self {
        Self::with_backend(cfg, Box::new(BehavioralBackend::new(&cfg.device)))
    }

    /// Engine with a custom analog backend (e.g. the PJRT artifact path).
    pub fn with_backend(cfg: &SimConfig, backend: Box<dyn AnalogBackend>) -> Self {
        let p = &cfg.device;
        let c_rbl = cfg.c_rbl();
        Self {
            cfg: cfg.clone(),
            array: FefetArray::new(cfg),
            energy: EnergyModel::new(cfg),
            cur_bank: CurrentSenseBank::new(CurrentRefs::derive(p, p.v_gread1, p.v_gread2)),
            volt_bank: VoltageSenseBank::new(VoltageRefs::derive(
                p, p.v_gread1, p.v_gread2, c_rbl,
            )),
            backend,
            lut: crate::device::CellLut::new(p),
        }
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn array(&self) -> &FefetArray {
        &self.array
    }

    pub fn array_mut(&mut self) -> &mut FefetArray {
        &mut self.array
    }

    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    fn check_word(&self, row: usize, word: usize) -> Result<(), EngineError> {
        if row >= self.cfg.rows || word >= self.cfg.words_per_row() {
            return Err(EngineError::OutOfRange(format!(
                "row {row} word {word} (array {}x{} words/row {})",
                self.cfg.rows,
                self.cfg.cols,
                self.cfg.words_per_row()
            )));
        }
        Ok(())
    }

    fn word_cols(&self, word: usize) -> (usize, usize) {
        let lo = word * self.cfg.word_bits;
        (lo, lo + self.cfg.word_bits)
    }

    /// One asymmetric dual-row activation + sensing: the per-bit
    /// SenseOut vector (LSB first) for the addressed word columns.
    fn activate_and_sense(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Vec<SenseOut>, EngineError> {
        if row_a == row_b {
            return Err(EngineError::Unsupported(
                "dual-row activation requires two distinct rows".into(),
            ));
        }
        let p = self.cfg.device.clone();
        let (lo, hi) = self.word_cols(word);
        // record the array access (stats: dual activation + half-select)
        let (pol_a, pol_b, dvt_a, dvt_b) = self.array.planes(row_a, row_b, lo, hi);
        self.note_dual_access(lo, hi);
        let outs = match self.cfg.scheme {
            SensingScheme::Current => {
                let isl = self.backend.dc_isl(
                    &pol_a, &pol_b, &dvt_a, &dvt_b, p.v_gread1, p.v_gread2,
                );
                self.cur_bank.sense_all(&isl)
            }
            SensingScheme::VoltagePrecharged | SensingScheme::VoltageDischarged => {
                let vf = self.backend.transient_vfinal(
                    &pol_a, &pol_b, &dvt_a, &dvt_b, p.v_gread1, p.v_gread2,
                    self.cfg.c_rbl(),
                );
                self.volt_bank.sense_all(&vf)
            }
        };
        // sanity: the sense bank must produce a consistent (A,B) decode;
        // an OR=0/AND=1 column means the margins collapsed
        for (i, o) in outs.iter().enumerate() {
            if o.and && !o.or {
                return Err(EngineError::SenseFailure(format!(
                    "column {i}: AND asserted without OR — margin collapse"
                )));
            }
        }
        Ok(outs)
    }

    fn note_dual_access(&mut self, lo: usize, hi: usize) {
        // FefetArray::planes doesn't mutate stats; account the activation
        // here so both backends are counted identically.
        let cols = self.array.cols();
        let s = self.array_stats_mut();
        s.dual_activations += 1;
        s.half_selected_cols += (cols - (hi - lo)) as u64;
    }

    fn array_stats_mut(&mut self) -> &mut crate::array::ArrayStats {
        // small helper: FefetArray exposes stats by value; keep a shadow
        // counter through reset/read (see ArrayStats usage in tests).
        // Implemented via interior access on the array.
        self.array.stats_mut()
    }

    /// Public access to one dual-row activation + sensing over a word
    /// window — used by the vector/SIMD extension (`cim::vector`) and by
    /// ablation studies.  Counts one array activation.
    pub fn activate_word(
        &mut self,
        row_a: usize,
        row_b: usize,
        word: usize,
    ) -> Result<Vec<SenseOut>, EngineError> {
        self.check_word(row_a, word)?;
        self.check_word(row_b, word)?;
        self.activate_and_sense(row_a, row_b, word)
    }

    /// Assemble words from per-bit sense outputs.
    fn words_from(outs: &[SenseOut]) -> (u64, u64) {
        let mut a = 0u64;
        let mut b = 0u64;
        for (i, o) in outs.iter().enumerate() {
            if o.a() {
                a |= 1 << i;
            }
            if o.b {
                b |= 1 << i;
            }
        }
        (a, b)
    }

    fn bool_from(f: BoolFn, outs: &[SenseOut]) -> u64 {
        let mut v = 0u64;
        for (i, o) in outs.iter().enumerate() {
            let bit = match f {
                BoolFn::And => o.and,
                BoolFn::Or => o.or,
                BoolFn::Nand => !o.and,
                BoolFn::Nor => !o.or,
                BoolFn::Xor => o.xor(),
                BoolFn::Xnor => !o.xor(),
                BoolFn::AndNot => o.a() && !o.b,
                BoolFn::OrNot => o.a() || !o.b,
            };
            if bit {
                v |= 1 << i;
            }
        }
        v
    }

    /// Standard single-row read through the sensing path (LUT-fast).
    fn read_word_sensed(&mut self, addr: WordAddr) -> Result<u64, EngineError> {
        self.check_word(addr.row, addr.word)?;
        let vg = self.cfg.device.v_gread2;
        let s = self.lut.s(self.cfg.device.v_read);
        let (lo, hi) = self.word_cols(addr.word);
        self.array.stats_mut().reads += 1;
        let mut v = 0u64;
        for (i, c) in (lo..hi).enumerate() {
            let i_cell = self.lut.f(self.lut.u_of(
                vg,
                self.array.pol(addr.row, c),
                self.array.dvt(addr.row, c),
            )) * s;
            if self.cur_bank.sense_read(i_cell) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }
}

impl Engine for AdraEngine {
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError> {
        match *op {
            CimOp::Write { addr, value } => {
                self.check_word(addr.row, addr.word)?;
                self.array.write_word(addr.row, addr.word, value);
                Ok(CimResult { value: CimValue::None, cost: self.energy.write_cost() })
            }
            CimOp::Read(addr) => {
                let v = self.read_word_sensed(addr)?;
                Ok(CimResult { value: CimValue::Word(v), cost: self.energy.read_cost() })
            }
            CimOp::Read2 { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let outs = self.activate_and_sense(row_a, row_b, word)?;
                let (a, b) = Self::words_from(&outs);
                Ok(CimResult { value: CimValue::Pair(a, b), cost: self.energy.cim_cost() })
            }
            CimOp::Bool { f, row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let outs = self.activate_and_sense(row_a, row_b, word)?;
                let v = Self::bool_from(f, &outs);
                Ok(CimResult { value: CimValue::Word(v), cost: self.energy.cim_cost() })
            }
            CimOp::Add { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let outs = self.activate_and_sense(row_a, row_b, word)?;
                let r = ripple_add_sub(&outs, false);
                Ok(CimResult {
                    value: CimValue::Sum(r.as_unsigned()),
                    cost: self.energy.cim_cost(),
                })
            }
            CimOp::Sub { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let outs = self.activate_and_sense(row_a, row_b, word)?;
                let r = ripple_add_sub(&outs, true);
                Ok(CimResult {
                    value: CimValue::Diff(r.as_signed()),
                    cost: self.energy.cim_cost(),
                })
            }
            CimOp::Compare { row_a, row_b, word } => {
                self.check_word(row_a, word)?;
                self.check_word(row_b, word)?;
                let outs = self.activate_and_sense(row_a, row_b, word)?;
                let diff = ripple_add_sub(&outs, true);
                let res = if and_tree_equal(&diff.bits) {
                    CompareResult::Equal
                } else if diff.sign() {
                    CompareResult::Less
                } else {
                    CompareResult::Greater
                };
                Ok(CimResult {
                    value: CimValue::Ordering(res),
                    cost: self.energy.cim_cost(),
                })
            }
        }
    }

    /// ADRA has a native fused datapath: dual ops over the same operand
    /// pair share one asymmetric activation (`coordinator::fuse`).
    fn execute_fused(&mut self, ops: &[CimOp]) -> Option<Vec<Result<CimResult, EngineError>>> {
        Some(crate::coordinator::fuse::execute_fused(self, ops))
    }

    fn name(&self) -> &'static str {
        "adra"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine(scheme: SensingScheme) -> AdraEngine {
        let mut cfg = SimConfig::square(256, scheme);
        cfg.word_bits = 8;
        AdraEngine::new(&cfg)
    }

    fn setup(e: &mut AdraEngine, a: u64, b: u64) {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    }

    #[test]
    fn read2_recovers_both_words_single_access() {
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            setup(&mut e, 0xA5, 0x3C);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(0xA5, 0x3C), "{scheme:?}");
        }
    }

    #[test]
    fn all_boolean_functions_correct() {
        let mut rng = Rng::new(11);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..8 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                for f in BoolFn::ALL {
                    let r = e
                        .execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 })
                        .unwrap();
                    assert_eq!(
                        r.value,
                        CimValue::Word(f.apply(a, b, 0xFF)),
                        "{scheme:?} {f:?} a={a:#x} b={b:#x}"
                    );
                }
            }
        }
    }

    #[test]
    fn add_and_sub_match_integers() {
        let mut rng = Rng::new(13);
        for scheme in SensingScheme::ALL {
            let mut e = engine(scheme);
            for _ in 0..16 {
                let (a, b) = (rng.below(256), rng.below(256));
                setup(&mut e, a, b);
                let add = e.execute(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }).unwrap();
                assert_eq!(add.value, CimValue::Sum((a + b) as u128));
                let sub = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
                let sa = (a as i128) - if a >= 128 { 256 } else { 0 };
                let sb = (b as i128) - if b >= 128 { 256 } else { 0 };
                assert_eq!(sub.value, CimValue::Diff(sa - sb), "a={a} b={b} {scheme:?}");
            }
        }
    }

    #[test]
    fn compare_matches_signed_order() {
        let mut e = engine(SensingScheme::Current);
        for (a, b, expect) in [
            (5u64, 9u64, CompareResult::Less),
            (9, 5, CompareResult::Greater),
            (7, 7, CompareResult::Equal),
            (0x80, 0x7F, CompareResult::Less), // -128 < 127
        ] {
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Ordering(expect), "a={a:#x} b={b:#x}");
        }
    }

    #[test]
    fn single_access_for_cim_ops() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 3, 5);
        e.array_mut().reset_stats();
        e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1, "subtraction must be ONE access");
        assert_eq!(s.reads, 0);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut e = engine(SensingScheme::Current);
        assert!(matches!(
            e.execute(&CimOp::Read(WordAddr { row: 9999, word: 0 })),
            Err(EngineError::OutOfRange(_))
        ));
        assert!(matches!(
            e.execute(&CimOp::Sub { row_a: 0, row_b: 0, word: 0 }),
            Err(EngineError::Unsupported(_))
        ));
    }

    #[test]
    fn standard_read_via_sense_path() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 0xC3, 0);
        let r = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        assert_eq!(r.value, CimValue::Word(0xC3));
    }

    #[test]
    fn costs_attached_and_ordered() {
        let mut e = engine(SensingScheme::Current);
        setup(&mut e, 1, 2);
        let read = e.execute(&CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
        let cim = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert!(cim.cost.energy.total() > read.cost.energy.total());
        assert!(cim.cost.latency > read.cost.latency);
        // but FAR less than two reads (that's the point of the paper)
        assert!(cim.cost.energy.total() < 2.0 * read.cost.energy.total());
    }

    #[test]
    fn works_with_variation() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg.vt_sigma = 0.02; // 20 mV sigma
        let mut e = AdraEngine::new(&cfg);
        let mut rng = Rng::new(17);
        for _ in 0..16 {
            let (a, b) = (rng.below(256), rng.below(256));
            setup(&mut e, a, b);
            let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
            assert_eq!(r.value, CimValue::Pair(a, b), "variation broke sensing");
        }
    }
}
