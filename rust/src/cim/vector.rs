//! Row-wide vector (SIMD) CiM and multi-word wide arithmetic.
//!
//! The paper's Fig. 5(b) parallelism analysis assumes CiM over many words
//! of a row pair per activation (P = N_w,CiM / N_w,TOT).  `VectorEngine`
//! implements exactly that: one dual-row activation computes the op for
//! every selected word in the row simultaneously (the wordlines span the
//! whole row anyway), with energy accounted through
//! `EnergyModel::row_activation_energy`.
//!
//! §Perf (DESIGN.md §10): on the packed tiers the whole row is served
//! from `u64` word slices of the engine's row planes — per lane that is
//! two windowed loads and one `u128` add/sub, so a 1024-column row costs
//! ~16 word operations instead of 1024 per-column compute-module
//! evaluations.  The analog tiers still ripple per column; both paths
//! are bit-identical (pinned by `tests/tier_equivalence.rs`).
//!
//! Wide arithmetic chains the per-word carry: an m-word operand pair is
//! subtracted with ONE activation (all sense outputs latched), then the
//! carry chains across word boundaries — a `u128` carry chain on the
//! packed path.

use crate::cim::adra::{AdraEngine, RowActivation};
use crate::cim::ops::{CimValue, EngineError};
use crate::energy::{EnergyBreakdown, OpCost};
use crate::logic::ripple_add_sub;

/// Vector-op results: per-word values + the single-activation cost.
#[derive(Clone, Debug)]
pub struct VectorResult {
    pub values: Vec<CimValue>,
    pub cost: OpCost,
}

/// Row-wide vector operations over an `AdraEngine`.
pub struct VectorEngine<'a> {
    engine: &'a mut AdraEngine,
}

impl<'a> VectorEngine<'a> {
    pub fn new(engine: &'a mut AdraEngine) -> Self {
        Self { engine }
    }

    /// Cost of one full-row activation at parallelism P = 1.
    fn row_cost(&self) -> OpCost {
        let m = self.engine.energy_model();
        let scheme = self.engine.cfg().scheme;
        OpCost {
            energy: EnergyBreakdown {
                // row_activation_energy returns a total; attribute it to
                // the RBL+periphery aggregate for reporting purposes
                rbl: m.row_activation_energy(scheme, 1.0),
                ..EnergyBreakdown::default()
            },
            latency: m.t_cim(),
        }
    }

    /// Vector subtract: word_i(row_a) - word_i(row_b) for ALL words, one
    /// activation.  Returns one signed difference per word.
    pub fn sub_row(&mut self, row_a: usize, row_b: usize) -> Result<VectorResult, EngineError> {
        self.row_op(row_a, row_b, true)
    }

    /// Vector add over all words, one activation.
    pub fn add_row(&mut self, row_a: usize, row_b: usize) -> Result<VectorResult, EngineError> {
        self.row_op(row_a, row_b, false)
    }

    /// One whole-row activation + per-lane derivation: word slices of the
    /// packed row planes on the packed tiers, per-column ripple on the
    /// analog tiers.
    fn row_op(
        &mut self,
        row_a: usize,
        row_b: usize,
        sub: bool,
    ) -> Result<VectorResult, EngineError> {
        let wb = self.engine.cfg().word_bits;
        let cols = self.engine.cfg().cols;
        let values = match self.engine.activate_span(row_a, row_b, 0, cols)? {
            RowActivation::Packed => {
                // ceil-divide + per-lane width so an unvalidated config
                // (cols not a multiple of word_bits) still yields the
                // same lane shapes as the analog arm's chunks(wb)
                let lanes = (cols + wb - 1) / wb;
                let mut values = Vec::with_capacity(lanes);
                for l in 0..lanes {
                    let w = wb.min(cols - l * wb);
                    let (a, b) = self.engine.planes_window(l * wb, l * wb + w);
                    values.push(if sub {
                        CimValue::Diff(AdraEngine::signed_of(a, w) - AdraEngine::signed_of(b, w))
                    } else {
                        CimValue::Sum(a as u128 + b as u128)
                    });
                }
                values
            }
            RowActivation::Sense => self
                .engine
                .last_sense()
                .chunks(wb)
                .map(|w| {
                    if sub {
                        CimValue::Diff(ripple_add_sub(w, true).as_signed())
                    } else {
                        CimValue::Sum(ripple_add_sub(w, false).as_unsigned())
                    }
                })
                .collect(),
        };
        Ok(VectorResult { values, cost: self.row_cost() })
    }

    /// Wide subtraction: operands span `m_words` consecutive words
    /// (little-endian word order) in each row.  One activation over the
    /// word span; the carry chains across word boundaries — as a `u128`
    /// chain over the packed planes on the packed tiers.  Result is an
    /// (m*word_bits + 1)-bit signed value.
    pub fn sub_wide(
        &mut self,
        row_a: usize,
        row_b: usize,
        word_lo: usize,
        m_words: usize,
    ) -> Result<(i128, OpCost), EngineError> {
        assert!(m_words >= 1);
        let wb = self.engine.cfg().word_bits;
        assert!(m_words * wb <= 127, "wide result must fit i128");
        let lo = word_lo * wb;
        let hi = lo + m_words * wb;
        let n = m_words * wb;
        let diff = match self.engine.activate_span(row_a, row_b, lo, hi)? {
            RowActivation::Packed => {
                let (a, b) = self.engine.planes_window_wide(lo, hi);
                AdraEngine::signed_of_wide(a, n) - AdraEngine::signed_of_wide(b, n)
            }
            RowActivation::Sense => ripple_add_sub(self.engine.last_sense(), true).as_signed(),
        };
        Ok((diff, self.row_cost()))
    }

    /// In-memory argmin/argmax over the words of `rows` at `word`:
    /// a comparison tournament using single-access compares.
    /// Returns (index_of_max, compares_done, total cost).
    pub fn argmax(
        &mut self,
        rows: &[usize],
        word: usize,
    ) -> Result<(usize, usize, OpCost), EngineError> {
        assert!(!rows.is_empty());
        let wb = self.engine.cfg().word_bits;
        let lo = word * wb;
        let mut best = rows[0];
        let mut best_idx = 0;
        let mut compares = 0;
        let mut cost = OpCost::default();
        for (i, &row) in rows.iter().enumerate().skip(1) {
            let (neg, zero) = match self.engine.activate_span(row, best, lo, lo + wb)? {
                RowActivation::Packed => {
                    let (a, b) = self.engine.planes_window(lo, lo + wb);
                    let d = AdraEngine::signed_of(a, wb) - AdraEngine::signed_of(b, wb);
                    (d < 0, d == 0)
                }
                RowActivation::Sense => {
                    let diff = ripple_add_sub(self.engine.last_sense(), true);
                    (diff.sign(), diff.is_zero())
                }
            };
            compares += 1;
            cost = cost.then(&OpCost {
                energy: self.engine.energy_model().cim_cost().energy,
                latency: self.engine.energy_model().t_cim(),
            });
            if !neg && !zero {
                best = row;
                best_idx = i;
            }
        }
        Ok((best_idx, compares, cost))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{CimOp, Engine, WordAddr};
    use crate::config::{SensingScheme, SimConfig};
    use crate::util::rng::Rng;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    fn signed8(v: u64) -> i128 {
        (v as i128) - if v >= 128 { 256 } else { 0 }
    }

    #[test]
    fn sub_row_computes_every_word_in_one_activation() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        let mut rng = Rng::new(21);
        let words = cfg.words_per_row();
        let mut a_vals = Vec::new();
        let mut b_vals = Vec::new();
        for w in 0..words {
            let (a, b) = (rng.below(256), rng.below(256));
            e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: w }, value: a }).unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: w }, value: b }).unwrap();
            a_vals.push(a);
            b_vals.push(b);
        }
        e.array_mut().reset_stats();
        let mut v = VectorEngine::new(&mut e);
        let r = v.sub_row(0, 1).unwrap();
        assert_eq!(r.values.len(), words);
        for w in 0..words {
            assert_eq!(
                r.values[w],
                CimValue::Diff(signed8(a_vals[w]) - signed8(b_vals[w])),
                "word {w}"
            );
        }
        assert_eq!(e.array().stats().dual_activations, 1, "ONE activation for the row");
    }

    #[test]
    fn add_row_matches_scalar_adds() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        for w in 0..4 {
            e.execute(&CimOp::Write { addr: WordAddr { row: 2, word: w }, value: 10 * w as u64 + 5 }).unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 3, word: w }, value: 100 + w as u64 }).unwrap();
        }
        let mut v = VectorEngine::new(&mut e);
        let r = v.add_row(2, 3).unwrap();
        for w in 0..4 {
            assert_eq!(r.values[w], CimValue::Sum((10 * w as u64 + 5 + 100 + w as u64) as u128));
        }
    }

    #[test]
    fn wide_subtraction_chains_carry_across_words() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        // 24-bit operands across 3 x 8-bit words (little-endian)
        let a: u64 = 0x34_00_01; // low word 0x01, mid 0x00 -> borrow chains
        let b: u64 = 0x12_00_02;
        for w in 0..3 {
            e.execute(&CimOp::Write {
                addr: WordAddr { row: 0, word: w },
                value: (a >> (8 * w)) & 0xFF,
            })
            .unwrap();
            e.execute(&CimOp::Write {
                addr: WordAddr { row: 1, word: w },
                value: (b >> (8 * w)) & 0xFF,
            })
            .unwrap();
        }
        let mut v = VectorEngine::new(&mut e);
        let (diff, _) = v.sub_wide(0, 1, 0, 3).unwrap();
        assert_eq!(diff, (a as i128) - (b as i128));
    }

    #[test]
    fn wide_subtraction_negative_result() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        let a: u64 = 0x00_10_00;
        let b: u64 = 0x01_00_00;
        for w in 0..3 {
            e.execute(&CimOp::Write { addr: WordAddr { row: 4, word: w }, value: (a >> (8 * w)) & 0xFF }).unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 5, word: w }, value: (b >> (8 * w)) & 0xFF }).unwrap();
        }
        let mut v = VectorEngine::new(&mut e);
        let (diff, _) = v.sub_wide(4, 5, 0, 3).unwrap();
        assert_eq!(diff, (a as i128) - (b as i128));
        assert!(diff < 0);
    }

    /// Regression for the old per-word loop + stats fix-up hack: a
    /// row-wide op must record exactly ONE dual activation and ZERO
    /// half-selected columns (the whole row computes), and a wide op must
    /// half-select exactly the columns outside its word span — counted
    /// once, not once per word.
    #[test]
    fn row_wide_ops_record_exact_stats() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        e.array_mut().reset_stats();
        {
            let mut v = VectorEngine::new(&mut e);
            v.sub_row(0, 1).unwrap();
        }
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1, "one activation for the whole row");
        assert_eq!(s.half_selected_cols, 0, "full row: no half-selects");

        e.array_mut().reset_stats();
        {
            let mut v = VectorEngine::new(&mut e);
            v.sub_wide(0, 1, 1, 3).unwrap(); // 3 x 8-bit words of a 64-col row
        }
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 1, "one activation for the wide op");
        assert_eq!(
            s.half_selected_cols,
            (cfg.cols - 3 * cfg.word_bits) as u64,
            "half-selects counted once for the unspanned columns"
        );
    }

    #[test]
    fn row_ops_identical_under_masked_variation() {
        // the packed word-slice path under vt_sigma > 0 must match the
        // pure-analog mirror lane for lane (same seed -> same dvt plane)
        let mut c = cfg();
        c.vt_sigma = 0.02;
        let mut masked = AdraEngine::new(&c);
        assert!(masked.masked_active());
        let mut c_exact = c.clone();
        c_exact.tier = crate::config::FidelityTier::Exact;
        let mut mirror = AdraEngine::new(&c_exact);
        let mut rng = Rng::new(91);
        for w in 0..c.words_per_row() {
            let (a, b) = (rng.below(256), rng.below(256));
            for e in [&mut masked, &mut mirror] {
                e.execute(&CimOp::Write { addr: WordAddr { row: 6, word: w }, value: a }).unwrap();
                e.execute(&CimOp::Write { addr: WordAddr { row: 7, word: w }, value: b }).unwrap();
            }
        }
        let (m_sub, m_add, m_wide) = {
            let mut v = VectorEngine::new(&mut masked);
            (v.sub_row(6, 7).unwrap(), v.add_row(6, 7).unwrap(), v.sub_wide(6, 7, 1, 3).unwrap())
        };
        let (r_sub, r_add, r_wide) = {
            let mut v = VectorEngine::new(&mut mirror);
            (v.sub_row(6, 7).unwrap(), v.add_row(6, 7).unwrap(), v.sub_wide(6, 7, 1, 3).unwrap())
        };
        assert_eq!(m_sub.values, r_sub.values);
        assert_eq!(m_add.values, r_add.values);
        assert_eq!(m_wide.0, r_wide.0);
        assert_eq!(m_wide.1, r_wide.1, "wide cost must be tier-invariant");
        let s = masked.array().stats();
        assert!(s.det_cols > 0 && s.det_col_fraction() > 0.5, "{s:?}");
        assert_eq!(s.xval_mismatches, 0);
    }

    #[test]
    fn argmax_tournament() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        let vals = [13u64, 90, 2, 77, 55];
        for (i, &v) in vals.iter().enumerate() {
            e.execute(&CimOp::Write { addr: WordAddr { row: i, word: 0 }, value: v }).unwrap();
        }
        let rows: Vec<usize> = (0..vals.len()).collect();
        let mut v = VectorEngine::new(&mut e);
        let (idx, compares, cost) = v.argmax(&rows, 0).unwrap();
        assert_eq!(idx, 1, "max is 90 at index 1");
        assert_eq!(compares, vals.len() - 1);
        assert!(cost.energy.total() > 0.0);
    }

    #[test]
    fn vector_op_cheaper_than_per_word_ops() {
        // the point of P=1 operation: one activation amortizes the
        // wordline/decoder work across the whole row
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        let per_word = e.energy_model().cim_cost().energy.total()
            * cfg.words_per_row() as f64;
        let mut v = VectorEngine::new(&mut e);
        let row = v.sub_row(0, 1).unwrap();
        assert!(row.cost.energy.total() <= per_word * 1.05);
    }
}
