//! CiM operation and result types shared by the ADRA and baseline engines
//! and by the coordinator's request protocol.

use crate::energy::OpCost;
use crate::logic::CompareResult;

/// Two-operand Boolean functions computable in-memory.  With ADRA all of
/// them are single-access; prior-work symmetric activation covers only
/// the commutative ones that don't need A and B separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BoolFn {
    And,
    Or,
    Nand,
    Nor,
    Xor,
    Xnor,
    /// A AND NOT B — non-commutative; requires the one-to-one mapping.
    AndNot,
    /// A OR NOT B — non-commutative.
    OrNot,
}

impl BoolFn {
    pub const ALL: [BoolFn; 8] = [
        BoolFn::And,
        BoolFn::Or,
        BoolFn::Nand,
        BoolFn::Nor,
        BoolFn::Xor,
        BoolFn::Xnor,
        BoolFn::AndNot,
        BoolFn::OrNot,
    ];

    /// Reference semantics on words.
    pub fn apply(&self, a: u64, b: u64, mask: u64) -> u64 {
        let v = match self {
            BoolFn::And => a & b,
            BoolFn::Or => a | b,
            BoolFn::Nand => !(a & b),
            BoolFn::Nor => !(a | b),
            BoolFn::Xor => a ^ b,
            BoolFn::Xnor => !(a ^ b),
            BoolFn::AndNot => a & !b,
            BoolFn::OrNot => a | !b,
        };
        v & mask
    }

    /// Is the function symmetric in (A, B)?  Non-commutative functions are
    /// exactly the ones prior-work CiM cannot compute in a single access.
    pub fn commutative(&self) -> bool {
        !matches!(self, BoolFn::AndNot | BoolFn::OrNot)
    }
}

/// A word address: row + word index within the row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WordAddr {
    pub row: usize,
    pub word: usize,
}

/// One CiM operation.  Dual-operand ops address the same word index in
/// two different rows — the two cells of each column pair share a
/// senseline, which is what dual-row activation exploits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CimOp {
    /// Standard single-word read.
    Read(WordAddr),
    /// ADRA 2-words-in-one-access read (same word index, rows a/b).
    Read2 { row_a: usize, row_b: usize, word: usize },
    /// Bitwise Boolean function of two in-memory words.
    Bool { f: BoolFn, row_a: usize, row_b: usize, word: usize },
    /// word(row_a) + word(row_b), (n+1)-bit unsigned result.
    Add { row_a: usize, row_b: usize, word: usize },
    /// word(row_a) - word(row_b), two's complement, (n+1)-bit signed.
    Sub { row_a: usize, row_b: usize, word: usize },
    /// Three-way compare of the two words (two's-complement semantics).
    Compare { row_a: usize, row_b: usize, word: usize },
    /// Write an immediate to a word.
    Write { addr: WordAddr, value: u64 },
}

impl CimOp {
    /// Rows this op activates (for batching conflict detection).
    pub fn rows(&self) -> (usize, Option<usize>) {
        match *self {
            CimOp::Read(a) => (a.row, None),
            CimOp::Write { addr, .. } => (addr.row, None),
            CimOp::Read2 { row_a, row_b, .. }
            | CimOp::Bool { row_a, row_b, .. }
            | CimOp::Add { row_a, row_b, .. }
            | CimOp::Sub { row_a, row_b, .. }
            | CimOp::Compare { row_a, row_b, .. } => (row_a, Some(row_b)),
        }
    }

    pub fn is_write(&self) -> bool {
        matches!(self, CimOp::Write { .. })
    }

    /// Does this op consume BOTH operand rows in one activation (the ops
    /// dual-row activation exists for)?
    pub fn is_dual(&self) -> bool {
        matches!(
            self,
            CimOp::Read2 { .. }
                | CimOp::Bool { .. }
                | CimOp::Add { .. }
                | CimOp::Sub { .. }
                | CimOp::Compare { .. }
        )
    }

}

/// Values produced by an operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CimValue {
    Word(u64),
    /// Read2: both words from one access.
    Pair(u64, u64),
    /// Add: (n+1)-bit unsigned sum.
    Sum(u128),
    /// Sub: signed difference.
    Diff(i128),
    Ordering(CompareResult),
    /// Writes return nothing.
    None,
}

impl CimValue {
    pub fn word(&self) -> Option<u64> {
        match self {
            CimValue::Word(w) => Some(*w),
            _ => None,
        }
    }

    pub fn diff(&self) -> Option<i128> {
        match self {
            CimValue::Diff(d) => Some(*d),
            _ => None,
        }
    }
}

/// Result: value + attributed energy/latency cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CimResult {
    pub value: CimValue,
    pub cost: OpCost,
}

/// The engine interface the coordinator drives.
pub trait Engine: Send {
    /// Execute one operation against the engine's array state.
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError>;

    /// Execute a whole batch with activation fusion
    /// (`coordinator::fuse`) if this engine supports it, returning
    /// results in batch order.  `None` tells the caller to fall back to
    /// sequential `execute` — the default for engines without a fused
    /// datapath (e.g. the symmetric baseline).
    fn execute_fused(&mut self, ops: &[CimOp]) -> Option<Vec<Result<CimResult, EngineError>>> {
        let _ = ops;
        None
    }

    /// Snapshot of the engine's array access counters, if it has an
    /// array (used by the pool to surface per-tier activation counts in
    /// `RunMetrics` without touching the request hot path).
    fn array_stats(&self) -> Option<crate::array::ArrayStats> {
        None
    }

    /// Override per-op-class routing (calibration actuator).  Indexed by
    /// `planner::OpClass as usize`; `Some(executor)` pins that class,
    /// `None` restores the engine's own choice.  Engines without a
    /// routing decision (single-executor engines) ignore it — only
    /// routed engines like `planner::PlannedEngine` override this.
    fn set_routing(&mut self, forced: [Option<crate::planner::Executor>; 4]) {
        let _ = forced;
    }

    /// Engine label for metrics/reporting.
    fn name(&self) -> &'static str;
}

/// Engine failure modes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// Address outside the array.
    OutOfRange(String),
    /// The operation is not expressible on this engine in a single
    /// access (e.g. single-access subtraction on the symmetric baseline —
    /// the many-to-one mapping problem).
    Unsupported(String),
    /// Sensing failed (margin collapse — e.g. mis-biased wordlines).
    SenseFailure(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::OutOfRange(s) => write!(f, "address out of range: {s}"),
            EngineError::Unsupported(s) => write!(f, "unsupported operation: {s}"),
            EngineError::SenseFailure(s) => write!(f, "sense failure: {s}"),
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boolfn_semantics() {
        let mask = 0xFF;
        assert_eq!(BoolFn::And.apply(0b1100, 0b1010, mask), 0b1000);
        assert_eq!(BoolFn::Or.apply(0b1100, 0b1010, mask), 0b1110);
        assert_eq!(BoolFn::Xor.apply(0b1100, 0b1010, mask), 0b0110);
        assert_eq!(BoolFn::Nand.apply(0b1100, 0b1010, mask), 0xF7);
        assert_eq!(BoolFn::AndNot.apply(0b1100, 0b1010, mask), 0b0100);
        assert_eq!(BoolFn::OrNot.apply(0b1100, 0b1010, mask), 0xFD);
    }

    #[test]
    fn commutativity_classification() {
        assert!(BoolFn::And.commutative());
        assert!(BoolFn::Xor.commutative());
        assert!(!BoolFn::AndNot.commutative());
        assert!(!BoolFn::OrNot.commutative());
    }

    #[test]
    fn op_rows_extraction() {
        let op = CimOp::Sub { row_a: 3, row_b: 9, word: 1 };
        assert_eq!(op.rows(), (3, Some(9)));
        let r = CimOp::Read(WordAddr { row: 5, word: 0 });
        assert_eq!(r.rows(), (5, None));
        assert!(!r.is_write());
        assert!(CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 1 }.is_write());
    }

    #[test]
    fn dual_classification() {
        assert!(CimOp::Sub { row_a: 3, row_b: 9, word: 4 }.is_dual());
        assert!(CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }.is_dual());
        assert!(!CimOp::Read(WordAddr { row: 5, word: 2 }).is_dual());
        assert!(!CimOp::Write { addr: WordAddr { row: 0, word: 7 }, value: 1 }.is_dual());
    }
}
