//! `adra` — CLI for the ADRA computing-in-memory stack.
//!
//! Subcommands:
//!   figures   regenerate the paper's figures/tables (Figs. 1-7)
//!   run       drive a workload through the coordinator and report metrics
//!   validate  cross-check the Rust behavioral model against the AOT
//!             JAX/Pallas artifacts over PJRT
//!   margins   sense-margin analysis / asymmetry ablation

use adra::cim::{AdraEngine, BaselineEngine, Engine};
use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::figures;
use adra::metrics::RunMetrics;
use adra::runtime::AnalogRuntime;
use adra::sensing::MarginReport;
use adra::util::args::ArgParser;
use adra::util::table::{fmt_si, Table};
use adra::workload::{OpMix, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("figures") => cmd_figures(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("margins") => cmd_margins(&args[1..]),
        Some("mc") => cmd_mc(&args[1..]),
        Some("corners") => cmd_corners(&args[1..]),
        Some("ablation") => cmd_ablation(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "adra — ADRA computing-in-memory reproduction\n\n\
         commands:\n\
         \x20 figures   [--fig N|--all]        regenerate paper figures/tables\n\
         \x20 run       [--scheme S --size N --ops K --shards W --mix M]\n\
         \x20                                  drive a workload through the coordinator\n\
         \x20 validate  [--artifacts DIR]      cross-check Rust model vs AOT artifacts (PJRT)\n\
         \x20 margins   [--steps N]            sense-margin / asymmetry ablation\n\
         \x20 mc        [--sigma V --samples N] Monte-Carlo variability / yield analysis\n\
         \x20 corners   [--sigma V --samples N] temperature-corner margin/yield sweep\n\
         \x20 ablation  [--steps N]            V_GREAD1 bias-point ablation sweep\n\
         \x20 serve     [--shards W]           line-protocol server on stdin/stdout\n"
    );
}

fn parse_or_exit(parser: &ArgParser, args: &[String]) -> adra::util::args::Parsed {
    match parser.parse(args) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn cmd_figures(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra figures", "regenerate the paper's figures")
        .opt("fig", None, "figure number (1-7); omit for all")
        .flag("all", "print every figure");
    let p = parse_or_exit(&parser, args);
    let dev = SimConfig::default().device;
    let which: Vec<usize> = match p.get_usize("fig").unwrap_or(None) {
        Some(n) => vec![n],
        None => vec![1, 2, 3, 4, 5, 6, 7],
    };
    for n in which {
        match n {
            1 => figures::print_fig1(&dev),
            2 => figures::print_fig2(&dev),
            3 => figures::print_fig3(&dev),
            4 => figures::print_fig4(),
            5 => figures::print_fig5(),
            6 => figures::print_fig6(),
            7 => figures::print_fig7(),
            other => {
                eprintln!("no figure {other} (paper has figures 1-7)");
                return 2;
            }
        }
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra run", "drive a workload through the coordinator")
        .opt("scheme", Some("current"), "sensing scheme: current|v1|v2")
        .opt("size", Some("256"), "square array size")
        .opt("word-bits", Some("32"), "word width")
        .opt("ops", Some("20000"), "operations to issue")
        .opt("shards", Some("4"), "array shards / worker threads")
        .opt("mix", Some("sub"), "op mix: sub|balanced|subheavy")
        .opt("seed", Some("42"), "workload seed")
        .opt("tier", Some("digital"), "activation fidelity tier: digital|lut|exact")
        .opt(
            "mask-policy",
            Some("write"),
            "margin-mask policy under vt_sigma > 0: off|construction|write",
        )
        .flag("baseline", "run the near-memory baseline engine instead");
    let p = parse_or_exit(&parser, args);

    let mut cfg = SimConfig::default();
    cfg.scheme = match SensingScheme::parse(p.get_or("scheme", "current")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    cfg.tier = match adra::config::FidelityTier::parse(p.get_or("tier", "digital")) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    cfg.mask_policy = match adra::config::MaskPolicy::parse(p.get_or("mask-policy", "write")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    cfg.rows = p.get_usize("size").unwrap().unwrap();
    cfg.cols = cfg.rows;
    cfg.word_bits = p.get_usize("word-bits").unwrap().unwrap();
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e}");
        return 2;
    }
    let shards = p.get_usize("shards").unwrap().unwrap();
    let n_ops = p.get_usize("ops").unwrap().unwrap();
    let seed = p.get_usize("seed").unwrap().unwrap() as u64;
    let mix = match p.get_or("mix", "sub") {
        "sub" => OpMix::sub_only(),
        "balanced" => OpMix::balanced(),
        "subheavy" => OpMix::subtraction_heavy(),
        other => {
            eprintln!("unknown mix {other:?}");
            return 2;
        }
    };
    let baseline = p.flag("baseline");

    let cfg2 = cfg.clone();
    let coord = Coordinator::new(&cfg, shards, move |_| -> Box<dyn Engine> {
        if baseline {
            Box::new(BaselineEngine::new(&cfg2))
        } else {
            Box::new(AdraEngine::new(&cfg2))
        }
    });

    // pre-populate every shard with deterministic data
    let mut gen = WorkloadGen::new(&cfg, mix, seed);
    let mut setup = WorkloadGen::new(&cfg, OpMix::balanced(), seed ^ 0xFACE);
    for shard in 0..shards {
        for row in 0..cfg.rows.min(64) {
            for word in 0..cfg.words_per_row().min(8) {
                let v = setup.word_value();
                coord
                    .call(shard, adra::cim::CimOp::Write {
                        addr: adra::cim::WordAddr { row, word },
                        value: v,
                    })
                    .expect("setup write");
            }
        }
    }

    let t0 = std::time::Instant::now();
    let per_shard = n_ops / shards;
    let mut handles = Vec::new();
    let coord = std::sync::Arc::new(coord);
    for shard in 0..shards {
        let ops = gen.batch(per_shard);
        let c = coord.clone();
        handles.push(std::thread::spawn(move || {
            let res = c.call_batch(shard, &ops).expect("batch");
            res.iter().filter(|r| r.is_err()).count()
        }));
    }
    let mut errs = 0;
    for h in handles {
        errs += h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut m: RunMetrics = coord.metrics();
    m.wall_seconds = wall;
    println!(
        "{}",
        m.report(if baseline { "baseline" } else { "adra" })
    );
    println!(
        "harness: {} ops in {:.3} s wall = {:.1} kop/s (engine+coordinator), {errs} errors",
        per_shard * shards,
        wall,
        (per_shard * shards) as f64 / wall / 1e3
    );
    0
}

fn cmd_validate(args: &[String]) -> i32 {
    let parser = ArgParser::new(
        "adra validate",
        "cross-check the Rust behavioral device model against the AOT JAX/Pallas artifacts",
    )
    .opt("artifacts", Some("artifacts"), "artifact directory");
    let p = parse_or_exit(&parser, args);
    let dir = p.get_or("artifacts", "artifacts");

    let manifest = match adra::runtime::ArtifactManifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let rt = match AnalogRuntime::new(manifest) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT init failed: {e:#}");
            return 1;
        }
    };
    println!("PJRT platform: {}", rt.platform());

    let dev = SimConfig::default().device;
    let n = adra::config::N_COLS;
    let mut worst = 0.0f64;
    // all four stored-bit vectors across the whole artifact width
    for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
        let pol_a = vec![dev.pol_of_bit(a) as f32; n];
        let pol_b = vec![dev.pol_of_bit(b) as f32; n];
        let z = vec![0.0f32; n];
        let (isl, _, _) = rt
            .dc_isl(&pol_a, &pol_b, &z, &z, dev.v_gread1 as f32, dev.v_gread2 as f32)
            .expect("dc_isl");
        let want = adra::device::senseline_current(
            &dev,
            dev.pol_of_bit(a),
            dev.pol_of_bit(b),
            dev.v_gread1,
            dev.v_gread2,
            dev.v_read,
            0.0,
            0.0,
        );
        let got = isl[0] as f64;
        let rel = ((got - want) / want).abs();
        worst = worst.max(rel);
        println!(
            "dc_isl ({},{}) -> PJRT {} vs rust {}  (rel err {:.2e})",
            a as u8,
            b as u8,
            fmt_si(got, "A"),
            fmt_si(want, "A"),
            rel
        );
    }
    let ok = worst < 5e-4;
    println!(
        "cross-validation {}: worst relative error {:.2e} (budget 5e-4)",
        if ok { "PASSED" } else { "FAILED" },
        worst
    );
    if ok {
        0
    } else {
        1
    }
}

fn cmd_mc(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra mc", "Monte-Carlo variability / yield analysis")
        .opt("samples", Some("5000"), "samples per sigma point")
        .opt("target-ber", Some("0.001"), "yield target bit-error rate")
        .opt("seed", Some("7"), "sampling seed");
    let p = parse_or_exit(&parser, args);
    let samples = p.get_usize("samples").unwrap().unwrap();
    let target: f64 = p.get_f64("target-ber").unwrap().unwrap();
    let seed = p.get_usize("seed").unwrap().unwrap() as u64;

    let dev = SimConfig::default().device;
    let mc = adra::analysis::MonteCarlo::new(&dev);
    let mut t = Table::new(&["sigma(V_T)", "CiM BER", "read BER", "err 00/01/10/11"])
        .with_title("Monte-Carlo sensing yield vs V_T variation");
    for sigma in [0.0, 0.01, 0.02, 0.04, 0.06, 0.08, 0.12, 0.16] {
        let rep = mc.run(sigma, samples, seed);
        t.row(&[
            format!("{:.0} mV", sigma * 1e3),
            format!("{:.2e}", rep.ber()),
            format!("{:.2e}", rep.read_ber()),
            format!(
                "{}/{}/{}/{}",
                rep.errors[0], rep.errors[1], rep.errors[2], rep.errors[3]
            ),
        ]);
    }
    t.print();
    let max_sigma = mc.max_tolerable_sigma(target, samples, seed);
    println!(
        "max tolerable sigma(V_T) for BER <= {target:.0e}: ~{:.0} mV \
         (memory window {} mV)",
        max_sigma * 1e3,
        dev.dvt_mw * 1e3
    );
    0
}

fn cmd_corners(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra corners", "temperature-corner margin/yield sweep")
        .opt("sigma", Some("0.02"), "probe sigma(V_T) for BER")
        .opt("samples", Some("2000"), "MC samples per corner");
    let p = parse_or_exit(&parser, args);
    let sigma = p.get_f64("sigma").unwrap().unwrap();
    let samples = p.get_usize("samples").unwrap().unwrap();
    let dev = SimConfig::default().device;
    let mut t = Table::new(&["T", "one-to-one", "I margin", "V margin", "BER"])
        .with_title(format!(
            "temperature corners at sigma(V_T) = {:.0} mV (artifacts calibrated at 300 K)",
            sigma * 1e3
        ));
    for c in adra::analysis::temperature_sweep(
        &dev,
        &adra::analysis::corners::INDUSTRIAL_TEMPS,
        sigma,
        samples,
    ) {
        t.row(&[
            format!("{:.0} K ({:+.0} C)", c.t_kelvin, c.t_kelvin - 273.0),
            c.margins.one_to_one.to_string(),
            fmt_si(c.margins.current_margin, "A"),
            fmt_si(c.margins.voltage_margin, "V"),
            format!("{:.2e}", c.ber),
        ]);
    }
    t.print();
    0
}

fn cmd_ablation(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra ablation", "V_GREAD1 bias-point ablation")
        .opt("steps", Some("16"), "sweep points")
        .opt("sigma", Some("0.02"), "probe sigma for BER")
        .opt("samples", Some("1000"), "MC samples per point");
    let p = parse_or_exit(&parser, args);
    let steps = p.get_usize("steps").unwrap().unwrap();
    let sigma = p.get_f64("sigma").unwrap().unwrap();
    let samples = p.get_usize("samples").unwrap().unwrap();

    let dev = SimConfig::default().device;
    let pts = adra::analysis::bias_ablation(&dev, steps, sigma, samples);
    let mut t = Table::new(&["V_GREAD1", "one-to-one", "I margin", "V margin", "BER"])
        .with_title(format!(
            "bias ablation at sigma(V_T) = {:.0} mV (paper choice: {} V)",
            sigma * 1e3,
            dev.v_gread1
        ));
    for b in &pts {
        t.row(&[
            format!("{:.3} V", b.vg1),
            b.margins.one_to_one.to_string(),
            fmt_si(b.margins.current_margin, "A"),
            fmt_si(b.margins.voltage_margin, "V"),
            format!("{:.2e}", b.ber),
        ]);
    }
    t.print();
    let best = adra::analysis::ablation::best_bias(&pts);
    println!("best worst-case-margin bias: V_GREAD1 = {:.3} V", best.vg1);
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra serve", "line-protocol server on stdin/stdout")
        .opt("shards", Some("2"), "array shards")
        .opt("size", Some("256"), "square array size")
        .opt("word-bits", Some("32"), "word width");
    let p = parse_or_exit(&parser, args);
    let mut cfg = SimConfig::default();
    cfg.rows = p.get_usize("size").unwrap().unwrap();
    cfg.cols = cfg.rows;
    cfg.word_bits = p.get_usize("word-bits").unwrap().unwrap();
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e}");
        return 2;
    }
    let shards = p.get_usize("shards").unwrap().unwrap();
    let coord = Coordinator::adra(&cfg, shards);
    eprintln!(
        "adra serve: {} shards of {}x{}, {}-bit words; commands on stdin",
        shards, cfg.rows, cfg.cols, cfg.word_bits
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    match adra::coordinator::repl::serve(&coord, stdin.lock(), stdout.lock()) {
        Ok(served) => {
            eprintln!("served {served} ops");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_margins(args: &[String]) -> i32 {
    let parser = ArgParser::new("adra margins", "sense-margin / asymmetry ablation")
        .opt("steps", Some("12"), "asymmetry sweep points");
    let p = parse_or_exit(&parser, args);
    let steps = p.get_usize("steps").unwrap().unwrap();
    let dev = SimConfig::default().device;
    let c_rbl = 1024.0 * dev.c_rbl_cell;

    let mut t = Table::new(&[
        "V_GREAD1",
        "one-to-one",
        "current margin",
        "voltage margin",
        "meets targets",
    ])
    .with_title("asymmetry ablation: shrinking V_GREAD2 - V_GREAD1");
    for i in 0..=steps {
        let vg1 = dev.v_gread2 - (i as f64 / steps as f64) * (dev.v_gread2 - 0.5);
        let r = MarginReport::evaluate(&dev, vg1, dev.v_gread2, c_rbl);
        t.row(&[
            format!("{vg1:.3} V"),
            r.one_to_one.to_string(),
            fmt_si(r.current_margin, "A"),
            fmt_si(r.voltage_margin, "V"),
            r.meets_paper_targets().to_string(),
        ]);
    }
    t.print();
    println!(
        "paper operating point: V_GREAD1 = {} V, V_GREAD2 = {} V",
        dev.v_gread1, dev.v_gread2
    );
    0
}
