//! Endurance modeling and wear leveling.
//!
//! The paper (§II.B) lists endurance as a key FeFET challenge: HZO
//! devices survive ~1e5-1e11 program/erase cycles depending on the stack.
//! This module adds (a) per-row wear accounting on top of the array's
//! write statistics and (b) a round-robin logical->physical row remapper
//! that levels wear for write-heavy CiM workloads (e.g. the accumulator
//! rows of an in-memory subtract-accumulate loop).

use std::collections::HashMap;

use crate::observe::Registry;

/// Wear state of an array bank.
#[derive(Clone, Debug)]
pub struct WearTracker {
    rows: usize,
    writes_per_row: Vec<u64>,
    /// device endurance budget (program/erase cycles per cell).
    endurance: u64,
}

impl WearTracker {
    pub fn new(rows: usize, endurance: u64) -> Self {
        Self { rows, writes_per_row: vec![0; rows], endurance }
    }

    pub fn note_write(&mut self, row: usize) {
        self.writes_per_row[row] += 1;
    }

    /// Record `n` writes at once (serve-side accounting batches per
    /// round; fault injection's endurance-drift acceleration multiplies
    /// `n`).
    pub fn note_writes(&mut self, row: usize, n: u64) {
        self.writes_per_row[row] += n;
    }

    pub fn writes(&self, row: usize) -> u64 {
        self.writes_per_row[row]
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Raw per-row counters (the durable store checkpoints these).
    pub fn counts(&self) -> &[u64] {
        &self.writes_per_row
    }

    /// Restore counters from a checkpoint.  Row counts beyond the
    /// tracker's geometry are dropped; missing rows stay at zero.
    pub fn seed_counts(&mut self, counts: &[u64]) {
        for (row, &n) in counts.iter().take(self.rows).enumerate() {
            self.writes_per_row[row] = n;
        }
    }

    /// The least-worn row among `candidates` (`None` when empty).
    pub fn coldest_of<I: IntoIterator<Item = usize>>(&self, candidates: I) -> Option<usize> {
        candidates
            .into_iter()
            .filter(|&r| r < self.rows)
            .min_by_key(|&r| (self.writes_per_row[r], r))
    }

    pub fn max_wear(&self) -> u64 {
        self.writes_per_row.iter().copied().max().unwrap_or(0)
    }

    pub fn total_writes(&self) -> u64 {
        self.writes_per_row.iter().sum()
    }

    /// Wear imbalance: max / mean (1.0 = perfectly level).
    pub fn imbalance(&self) -> f64 {
        let total = self.total_writes();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.rows as f64;
        self.max_wear() as f64 / mean
    }

    /// Remaining lifetime fraction of the worst row.
    pub fn lifetime_remaining(&self) -> f64 {
        1.0 - (self.max_wear() as f64 / self.endurance as f64).min(1.0)
    }

    pub fn is_worn_out(&self) -> bool {
        self.max_wear() >= self.endurance
    }

    /// Mirror wear state into the registry under a `shard` label
    /// (`source="endurance"` keeps these rows distinct from the
    /// engine-level `adra.array.writes` series published by
    /// `RunMetrics`).  Counters ratchet so re-publishing cumulative
    /// totals is idempotent; the `array_wear_rate` health rule watches
    /// the write counter (ROADMAP item 5b pre-work).
    pub fn publish(&self, reg: &Registry, shard: &str) {
        let l: [(&str, &str); 2] = [("shard", shard), ("source", "endurance")];
        reg.counter("adra.array.writes", "Array write operations.", &l)
            .set_at_least(self.total_writes());
        reg.gauge("adra.array.wear_max", "Program/erase cycles on the hottest row.", &l)
            .set_at_least(self.max_wear() as f64);
        reg.gauge("adra.array.wear_imbalance", "Hottest-row wear over mean wear (1.0 = level).", &l)
            .set(self.imbalance());
        reg.gauge(
            "adra.array.lifetime_remaining",
            "Remaining endurance fraction of the worst row.",
            &l,
        )
        .set(self.lifetime_remaining());
    }
}

/// Round-robin wear leveler: logical rows are periodically remapped onto
/// the least-worn physical rows.  The caller owns data migration (it
/// knows whether a remap implies a copy); the leveler provides the map.
#[derive(Clone, Debug)]
pub struct WearLeveler {
    map: HashMap<usize, usize>,
    tracker: WearTracker,
    /// remap whenever the hottest row exceeds the coldest by this many
    /// writes.
    threshold: u64,
    remaps: u64,
}

impl WearLeveler {
    pub fn new(rows: usize, endurance: u64, threshold: u64) -> Self {
        Self {
            map: HashMap::new(),
            tracker: WearTracker::new(rows, endurance),
            threshold,
            remaps: 0,
        }
    }

    pub fn tracker(&self) -> &WearTracker {
        &self.tracker
    }

    pub fn remaps(&self) -> u64 {
        self.remaps
    }

    /// Physical row currently backing a logical row.
    pub fn physical(&self, logical: usize) -> usize {
        *self.map.get(&logical).unwrap_or(&logical)
    }

    /// Record a write to a logical row; returns `Some((from, to))` when
    /// the caller must migrate the row's data to a new physical row.
    pub fn on_write(&mut self, logical: usize) -> Option<(usize, usize)> {
        let phys = self.physical(logical);
        self.tracker.note_write(phys);
        let hot = self.tracker.writes(phys);
        // find the coldest physical row not currently mapped to
        let (cold_row, cold_writes) = (0..self.tracker.rows)
            .filter(|r| !self.is_mapped_target(*r) || *r == phys)
            .map(|r| (r, self.tracker.writes(r)))
            .min_by_key(|&(_, w)| w)
            .unwrap();
        if hot >= cold_writes + self.threshold && cold_row != phys {
            self.map.insert(logical, cold_row);
            self.remaps += 1;
            Some((phys, cold_row))
        } else {
            None
        }
    }

    fn is_mapped_target(&self, phys: usize) -> bool {
        self.map.values().any(|&v| v == phys)
    }

    /// Publish the tracker's wear state plus the remap counter.
    pub fn publish(&self, reg: &Registry, shard: &str) {
        self.tracker.publish(reg, shard);
        reg.counter(
            "adra.array.wear_remaps",
            "Wear-leveling row remaps (each implies a data migration).",
            &[("shard", shard), ("source", "endurance")],
        )
        .set_at_least(self.remaps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_accounts_and_reports() {
        let mut t = WearTracker::new(4, 1000);
        for _ in 0..10 {
            t.note_write(1);
        }
        t.note_write(2);
        assert_eq!(t.writes(1), 10);
        assert_eq!(t.max_wear(), 10);
        assert_eq!(t.total_writes(), 11);
        assert!(t.imbalance() > 3.0);
        assert!(!t.is_worn_out());
        assert!((t.lifetime_remaining() - 0.99).abs() < 1e-9);
    }

    #[test]
    fn counts_round_trip_and_batched_notes() {
        let mut t = WearTracker::new(4, 1000);
        t.note_writes(2, 7);
        t.note_write(2);
        assert_eq!(t.counts(), &[0, 0, 8, 0]);
        let mut restored = WearTracker::new(4, 1000);
        restored.seed_counts(t.counts());
        assert_eq!(restored.counts(), t.counts());
        // geometry mismatch: extra rows dropped, missing stay zero
        let mut small = WearTracker::new(2, 1000);
        small.seed_counts(&[5, 6, 7]);
        assert_eq!(small.counts(), &[5, 6]);
        assert_eq!(t.coldest_of([2usize, 1, 3]), Some(1), "ties break low");
        assert_eq!(t.coldest_of(Vec::<usize>::new()), None);
    }

    #[test]
    fn wearout_detection() {
        let mut t = WearTracker::new(2, 5);
        for _ in 0..5 {
            t.note_write(0);
        }
        assert!(t.is_worn_out());
        assert_eq!(t.lifetime_remaining(), 0.0);
    }

    #[test]
    fn leveler_spreads_a_hot_row() {
        let mut l = WearLeveler::new(8, 1_000_000, 10);
        let mut migrations = 0;
        for _ in 0..200 {
            if l.on_write(0).is_some() {
                migrations += 1;
            }
        }
        assert!(migrations > 0, "hot row never remapped");
        assert!(
            l.tracker().imbalance() < 3.0,
            "imbalance {} not leveled",
            l.tracker().imbalance()
        );
    }

    #[test]
    fn leveler_beats_no_leveling() {
        // same write stream with and without leveling
        let mut unleveled = WearTracker::new(8, 1_000_000);
        let mut leveled = WearLeveler::new(8, 1_000_000, 10);
        for _ in 0..400 {
            unleveled.note_write(3);
            leveled.on_write(3);
        }
        assert!(leveled.tracker().max_wear() < unleveled.max_wear() / 2);
    }

    #[test]
    fn cold_rows_untouched_by_cold_workload() {
        let mut l = WearLeveler::new(8, 1_000_000, 10);
        for r in 0..8 {
            l.on_write(r);
        }
        assert_eq!(l.remaps(), 0, "uniform workload must not remap");
        assert!((l.tracker().imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn publish_mirrors_wear_into_registry() {
        let reg = Registry::new();
        let mut l = WearLeveler::new(8, 1_000, 10);
        for _ in 0..100 {
            l.on_write(0);
        }
        l.publish(&reg, "3");
        l.publish(&reg, "3"); // idempotent ratchet
        let text = crate::observe::expose_text(&reg);
        assert!(
            text.contains("adra_array_writes{shard=\"3\",source=\"endurance\"} 100"),
            "{text}"
        );
        assert!(text.contains("adra_array_wear_remaps{shard=\"3\",source=\"endurance\"}"), "{text}");
        assert!(text.contains("adra_array_wear_imbalance{shard=\"3\",source=\"endurance\"}"), "{text}");
        assert!(
            text.contains("adra_array_lifetime_remaining{shard=\"3\",source=\"endurance\"}"),
            "{text}"
        );
    }

    #[test]
    fn physical_mapping_is_stable_between_remaps() {
        let mut l = WearLeveler::new(4, 1_000_000, 1000);
        let before = l.physical(2);
        for _ in 0..100 {
            l.on_write(2);
        }
        // below threshold: mapping unchanged
        assert_eq!(l.physical(2), before);
    }
}
