//! The 1T-FeFET array: rows x cols of polarization state with a digital
//! bit view, per-cell V_T variation, word-level accessors, access
//! statistics (including half-select counts for the Fig. 5(b) analysis),
//! and the packed planes of the digital fast path: the bit shadow plane
//! plus the variation-aware margin-mask plane (DESIGN.md §10).

use crate::config::{DeviceParams, MaskPolicy, SimConfig, VT_SEED_SALT};
use crate::device;
use crate::sensing::DvtBudget;
use crate::util::rng::Rng;

/// All-ones mask of an `n`-bit window (`n <= 64`).
#[inline]
pub fn width_mask(n: usize) -> u64 {
    debug_assert!(n <= 64);
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Extract an `n <= 64`-bit window of a packed plane at bit offset `lo`
/// (LSB = bit `lo`), straddling `u64` word boundaries.  The single shift
/// helper shared by the shadow window, the mask window, and the engine's
/// packed row planes — including the `n == 64` boundary cases that a
/// naive `(1 << n) - 1` mask would overflow on.
#[inline]
pub fn plane_window(plane: &[u64], lo: usize, n: usize) -> u64 {
    debug_assert!(n >= 1 && n <= 64, "window width {n} out of range 1..=64");
    let w0 = lo / 64;
    let off = lo % 64;
    let mut v = plane[w0] >> off;
    if off != 0 && off + n > 64 {
        v |= plane[w0 + 1] << (64 - off);
    }
    if n < 64 {
        v &= (1u64 << n) - 1;
    }
    v
}

/// Set or clear one bit of a packed plane.
#[inline]
pub fn plane_set_bit(plane: &mut [u64], idx: usize, bit: bool) {
    let m = 1u64 << (idx % 64);
    if bit {
        plane[idx / 64] |= m;
    } else {
        plane[idx / 64] &= !m;
    }
}

/// Access/energy-relevant event counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArrayStats {
    pub writes: u64,
    pub reads: u64,
    pub dual_activations: u64,
    /// Column accesses on words NOT selected by the operation but sharing
    /// the asserted wordline(s) — the pseudo-CiM columns of scheme 1.
    pub half_selected_cols: u64,
    /// Dual activations served entirely by the bit-packed digital tier (a
    /// subset of `dual_activations`; the modeled cost is charged
    /// identically).
    pub digital_activations: u64,
    /// Dual activations served by the masked packed path under variation
    /// (deterministic columns from the shadow plane, marginal columns
    /// through the analog pipeline, merged by mask).
    pub masked_activations: u64,
    /// Columns served straight from the packed planes across all packed
    /// activations and reads (the deterministic-fraction numerator).
    pub det_cols: u64,
    /// Columns within packed-path activations/reads that fell back to the
    /// analog pipeline (the marginal minority).
    pub marginal_cols: u64,
    /// Sampled digital-vs-analog cross-validation checks run.
    pub xval_checks: u64,
    /// Cross-validation checks whose digital decisions diverged from the
    /// analog pipeline (must stay 0 on a calibrated configuration).
    pub xval_mismatches: u64,
}

impl ArrayStats {
    /// Field-wise sum — used when aggregating stats across engines or
    /// shards.
    pub fn merged(&self, other: &ArrayStats) -> ArrayStats {
        ArrayStats {
            writes: self.writes + other.writes,
            reads: self.reads + other.reads,
            dual_activations: self.dual_activations + other.dual_activations,
            half_selected_cols: self.half_selected_cols + other.half_selected_cols,
            digital_activations: self.digital_activations + other.digital_activations,
            masked_activations: self.masked_activations + other.masked_activations,
            det_cols: self.det_cols + other.det_cols,
            marginal_cols: self.marginal_cols + other.marginal_cols,
            xval_checks: self.xval_checks + other.xval_checks,
            xval_mismatches: self.xval_mismatches + other.xval_mismatches,
        }
    }

    /// Fraction of packed-path columns served deterministically (1.0 when
    /// nothing packed ran — an empty trajectory is not a regression).
    pub fn det_col_fraction(&self) -> f64 {
        let total = self.det_cols + self.marginal_cols;
        if total == 0 {
            1.0
        } else {
            self.det_cols as f64 / total as f64
        }
    }
}

/// Bit-accurate FeFET array with analog polarization state.
pub struct FefetArray {
    params: DeviceParams,
    rows: usize,
    cols: usize,
    word_bits: usize,
    /// Row-major polarization (C/m^2).
    pol: Vec<f64>,
    /// Per-cell V_T variation offsets (volts); zeros unless vt_sigma > 0.
    dvt: Vec<f64>,
    /// Bit-packed digital shadow of `pol` (one u64 per 64 columns per
    /// row, LSB = lowest column), kept coherent on every write/reset.
    /// This is the substrate of the `FidelityTier::Digital` fast path.
    shadow: Vec<u64>,
    /// Packed per-cell margin masks, same layout as `shadow`: a set bit
    /// means the cell's sampled dVt keeps every decision it can feed
    /// deterministic (classified against the sense references).  Empty
    /// when no classification ran (`vt_sigma == 0` or `MaskPolicy::Off`).
    mask: Vec<u64>,
    /// `vt_sigma == 0`: every cell is deterministic; `mask_window`
    /// short-circuits to all-ones without a mask plane.
    mask_all: bool,
    /// Per-stored-bit budgets for write-time reclassification
    /// (`MaskPolicy::Write` only; `Construction` masks are static).
    budget: Option<DvtBudget>,
    /// u64 words per row in `shadow` (and `mask`).
    shadow_stride: usize,
    stats: ArrayStats,
}

impl FefetArray {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.rows * cfg.cols;
        let dvt = if cfg.vt_sigma > 0.0 {
            let mut rng = Rng::new(cfg.seed ^ VT_SEED_SALT);
            (0..n).map(|_| rng.normal() * cfg.vt_sigma).collect()
        } else {
            vec![0.0; n]
        };
        let shadow_stride = (cfg.cols + 63) / 64;
        let mask_all = cfg.vt_sigma == 0.0;
        // only the Digital tier ever consults the mask plane; analog-tier
        // arrays skip the budget bisection + per-cell classification
        let wants_mask = cfg.tier == crate::config::FidelityTier::Digital
            && cfg.mask_policy != MaskPolicy::Off;
        let (mask, budget) = if !mask_all && wants_mask {
            let b = DvtBudget::derive(cfg);
            let mut mask = vec![0u64; cfg.rows * shadow_stride];
            for r in 0..cfg.rows {
                for c in 0..cfg.cols {
                    let d = dvt[r * cfg.cols + c];
                    // the global reset leaves every cell storing '0', so
                    // write-time classification starts from the 0-budget;
                    // construction-time uses the bit-independent one
                    let det = match cfg.mask_policy {
                        MaskPolicy::Write => b.classify(d, false),
                        _ => d.abs() <= b.sym(),
                    };
                    if det {
                        mask[r * shadow_stride + c / 64] |= 1u64 << (c % 64);
                    }
                }
            }
            let budget = (cfg.mask_policy == MaskPolicy::Write).then_some(b);
            (mask, budget)
        } else {
            (Vec::new(), None)
        };
        Self {
            params: cfg.device.clone(),
            rows: cfg.rows,
            cols: cfg.cols,
            word_bits: cfg.word_bits,
            // unwritten cells hold -P (HRS, '0') after a FLASH-like global
            // reset (paper §II.B); the shadow plane starts all-zero to
            // match
            pol: vec![cfg.device.pol_of_bit(false); n],
            dvt,
            shadow: vec![0u64; cfg.rows * shadow_stride],
            mask,
            mask_all,
            budget,
            shadow_stride,
            stats: ArrayStats::default(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn word_bits(&self) -> usize {
        self.word_bits
    }

    pub fn words_per_row(&self) -> usize {
        self.cols / self.word_bits
    }

    pub fn params(&self) -> &DeviceParams {
        &self.params
    }

    pub fn stats(&self) -> ArrayStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ArrayStats::default();
    }

    /// Mutable stats access — used by engines that evaluate the analog
    /// path through an external backend (PJRT) and account the array
    /// activation themselves.
    pub fn stats_mut(&mut self) -> &mut ArrayStats {
        &mut self.stats
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Stored polarization of a cell.
    pub fn pol(&self, row: usize, col: usize) -> f64 {
        self.pol[self.idx(row, col)]
    }

    /// V_T variation offset of a cell.
    pub fn dvt(&self, row: usize, col: usize) -> f64 {
        self.dvt[self.idx(row, col)]
    }

    /// Digital view: does the cell store '1' (positive polarization)?
    pub fn bit(&self, row: usize, col: usize) -> bool {
        self.pol[self.idx(row, col)] > 0.0
    }

    /// Write one bit (behavioral SET/RESET; counts one write access).
    /// Keeps the digital shadow plane coherent with the analog state, and
    /// under `MaskPolicy::Write` reclassifies the cell's margin-mask bit
    /// against the budget of the bit it now stores (rewrite invalidates
    /// the old classification).
    pub fn write_bit(&mut self, row: usize, col: usize, bit: bool) {
        let i = self.idx(row, col);
        self.pol[i] = device::write_bit(&self.params, bit);
        let w = row * self.shadow_stride + col / 64;
        let m = 1u64 << (col % 64);
        if bit {
            self.shadow[w] |= m;
        } else {
            self.shadow[w] &= !m;
        }
        if let Some(b) = self.budget {
            if b.classify(self.dvt[i], bit) {
                self.mask[w] |= m;
            } else {
                self.mask[w] &= !m;
            }
        }
        self.stats.writes += 1;
    }

    /// Write an n-bit word at `word_idx` within `row`, LSB at the lowest
    /// column (column = word_idx * word_bits + bit_position).
    pub fn write_word(&mut self, row: usize, word_idx: usize, value: u64) {
        assert!(word_idx < self.words_per_row(), "word index out of range");
        let base = word_idx * self.word_bits;
        for b in 0..self.word_bits {
            self.write_bit(row, base + b, (value >> b) & 1 == 1);
        }
        // word write is one array access regardless of width
        self.stats.writes = self.stats.writes - self.word_bits as u64 + 1;
    }

    /// Digital word view (no analog access, no stats).
    pub fn peek_word(&self, row: usize, word_idx: usize) -> u64 {
        let base = word_idx * self.word_bits;
        let mut v = 0u64;
        for b in 0..self.word_bits {
            if self.bit(row, base + b) {
                v |= 1 << b;
            }
        }
        v
    }

    /// Single-row read of the column range `[col_lo, col_hi)`: per-column
    /// cell currents at the read operating point.  Counts a read access.
    pub fn read_currents(&mut self, row: usize, col_lo: usize, col_hi: usize, vg: f64) -> Vec<f64> {
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.reads += 1;
        (col_lo..col_hi)
            .map(|c| {
                let i = self.idx(row, c);
                device::cell_current(&self.params, vg, self.params.v_read, self.pol[i], self.dvt[i])
            })
            .collect()
    }

    /// ADRA dual-row activation over `[col_lo, col_hi)`: per-column
    /// senseline currents with row_a at `vg1` and row_b at `vg2`.
    ///
    /// Because the wordlines span the whole row, all other columns are
    /// half-selected; the count is recorded for the scheme-1 pseudo-CiM
    /// energy accounting (Fig. 5(b)).
    pub fn dual_row_currents(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        assert!(row_a != row_b, "dual activation needs distinct rows");
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::senseline_current(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Dual-row discharge transients (voltage sensing) over the column
    /// range; `c_rbl` is the per-column bitline capacitance.
    pub fn dual_row_transients(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<device::RblTransient> {
        assert!(row_a != row_b);
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::rbl_transient(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    c_rbl,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Raw planes for a row pair + column range, in the layout the AOT
    /// `dc_isl` / `transient_cim` artifacts take (used by the PJRT path).
    pub fn planes(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut pol_a = Vec::new();
        let mut pol_b = Vec::new();
        let mut dvt_a = Vec::new();
        let mut dvt_b = Vec::new();
        self.planes_into(
            row_a, row_b, col_lo, col_hi, &mut pol_a, &mut pol_b, &mut dvt_a, &mut dvt_b,
        );
        (pol_a, pol_b, dvt_a, dvt_b)
    }

    /// `planes`, but writing into caller-owned buffers (cleared first) —
    /// the zero-allocation analog hot path reuses engine scratch here.
    #[allow(clippy::too_many_arguments)]
    pub fn planes_into(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        pol_a: &mut Vec<f32>,
        pol_b: &mut Vec<f32>,
        dvt_a: &mut Vec<f32>,
        dvt_b: &mut Vec<f32>,
    ) {
        pol_a.clear();
        pol_b.clear();
        dvt_a.clear();
        dvt_b.clear();
        for c in col_lo..col_hi {
            let ia = self.idx(row_a, c);
            let ib = self.idx(row_b, c);
            pol_a.push(self.pol[ia] as f32);
            pol_b.push(self.pol[ib] as f32);
            dvt_a.push(self.dvt[ia] as f32);
            dvt_b.push(self.dvt[ib] as f32);
        }
    }

    /// Bit-packed view of the column window `[col_lo, col_hi)` of a row
    /// (at most 64 columns, LSB = `col_lo`), straight from the shadow
    /// plane — no analog access, no stats.  Delegates to [`plane_window`],
    /// whose masking is safe for full 64-bit windows (the former inline
    /// `1u64 << n` mask would overflow at `n == 64` without the width
    /// guard; the shared helper keeps that guard in exactly one place).
    pub fn packed_window(&self, row: usize, col_lo: usize, col_hi: usize) -> u64 {
        debug_assert!(col_lo < col_hi && col_hi <= self.cols);
        debug_assert!(col_hi - col_lo <= 64);
        plane_window(self.shadow_row(row), col_lo, col_hi - col_lo)
    }

    /// Margin-mask view of the column window (same addressing as
    /// `packed_window`): set bits mark deterministically-resolvable
    /// cells.  All-ones without variation; all-zeros when no
    /// classification ran (`MaskPolicy::Off` under variation).
    pub fn mask_window(&self, row: usize, col_lo: usize, col_hi: usize) -> u64 {
        debug_assert!(col_lo < col_hi && col_hi <= self.cols);
        let n = col_hi - col_lo;
        debug_assert!(n <= 64);
        if self.mask_all {
            return width_mask(n);
        }
        if self.mask.is_empty() {
            return 0;
        }
        let base = row * self.shadow_stride;
        plane_window(&self.mask[base..base + self.shadow_stride], col_lo, n)
    }

    /// The whole shadow row (one u64 per 64 columns, LSB-first).
    pub fn shadow_row(&self, row: usize) -> &[u64] {
        let base = row * self.shadow_stride;
        &self.shadow[base..base + self.shadow_stride]
    }

    /// Was a margin-mask plane classified for this array?
    pub fn has_mask(&self) -> bool {
        !self.mask.is_empty()
    }

    /// FNV-1a digest over the FULL physical state: analog polarization
    /// bit patterns, the packed shadow plane, and the margin-mask plane.
    /// Two arrays with equal digests are bit-identical in every plane —
    /// the witness the durability crash-recovery suites compare.
    ///
    /// Write-order independence makes this usable for replay proofs: a
    /// cell's polarization and shadow bit depend only on the LAST bit
    /// written (`device::write_bit` is drift-free), and `MaskPolicy::Write`
    /// reclassification likewise depends only on the stored bit — so the
    /// digest is a pure function of (config, final logical contents).
    pub fn state_digest(&self) -> u64 {
        fn mix(mut h: u64, v: u64) -> u64 {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h
        }
        let mut h: u64 = 0xcbf29ce484222325;
        for &w in &self.shadow {
            h = mix(h, w);
        }
        for &w in &self.mask {
            h = mix(h, w);
        }
        for &p in &self.pol {
            h = mix(h, p.to_bits());
        }
        h
    }

    /// Fraction of cells currently classified deterministic (1.0 without
    /// variation, 0.0 when classification is off under variation).
    pub fn deterministic_fraction(&self) -> f64 {
        if self.mask_all {
            return 1.0;
        }
        if self.mask.is_empty() {
            return 0.0;
        }
        let ones: u64 = self.mask.iter().map(|w| w.count_ones() as u64).sum();
        ones as f64 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg
    }

    #[test]
    fn fresh_array_is_all_zeros() {
        let arr = FefetArray::new(&small_cfg());
        for r in 0..4 {
            for c in 0..8 {
                assert!(!arr.bit(r, c));
            }
        }
    }

    #[test]
    fn word_write_read_roundtrip() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(3, 2, 0xA5);
        assert_eq!(arr.peek_word(3, 2), 0xA5);
        assert_eq!(arr.peek_word(3, 1), 0); // neighbors untouched
        assert_eq!(arr.peek_word(3, 3), 0);
    }

    #[test]
    fn word_write_masks_to_width() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0x1FF); // 9 bits into an 8-bit word
        assert_eq!(arr.peek_word(0, 0), 0xFF);
    }

    #[test]
    fn dual_row_currents_reflect_bits() {
        let p = DeviceParams::default();
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0b01); // A: bit0=1
        arr.write_word(1, 0, 0b10); // B: bit1=1
        let isl = arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        let levels = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        let tol = 1e-9;
        assert!((isl[0] - levels[0b10]).abs() < tol); // A=1,B=0
        assert!((isl[1] - levels[0b01]).abs() < tol); // A=0,B=1
        assert!((isl[2] - levels[0b00]).abs() < tol); // A=0,B=0
    }

    #[test]
    fn half_select_accounting() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64);
        arr.dual_row_currents(0, 1, 0, 64, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64); // full row adds 0
    }

    #[test]
    fn variation_plane_statistics() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let arr = FefetArray::new(&cfg);
        let n = (cfg.rows * cfg.cols) as f64;
        let mean: f64 = (0..cfg.rows)
            .flat_map(|r| (0..cfg.cols).map(move |c| (r, c)))
            .map(|(r, c)| arr.dvt(r, c))
            .sum::<f64>()
            / n;
        assert!(mean.abs() < 0.005, "mean {mean}");
        assert!(arr.dvt(0, 0) != arr.dvt(0, 1) || arr.dvt(1, 0) != arr.dvt(1, 1));
    }

    #[test]
    fn deterministic_variation_given_seed() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let a = FefetArray::new(&cfg);
        let b = FefetArray::new(&cfg);
        assert_eq!(a.dvt(5, 5), b.dvt(5, 5));
    }

    #[test]
    fn planes_layout_matches_state() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(2, 0, 0xFF);
        let (pa, pb, da, _db) = arr.planes(2, 3, 0, 8);
        assert_eq!(pa.len(), 8);
        assert!(pa.iter().all(|&x| x > 0.0)); // row 2 all ones
        assert!(pb.iter().all(|&x| x < 0.0)); // row 3 all zeros
        assert!(da.iter().all(|&x| x == 0.0)); // no variation configured
    }

    #[test]
    fn shadow_plane_coherent_with_bits() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(1, 0, 0xA5);
        arr.write_word(1, 3, 0x3C);
        arr.write_bit(1, 40, true);
        arr.write_bit(1, 40, false); // reset must clear the shadow too
        for c in 0..64 {
            let from_shadow = (arr.packed_window(1, c, c + 1)) & 1 == 1;
            assert_eq!(from_shadow, arr.bit(1, c), "col {c}");
        }
        // packed word view matches the digital word view
        assert_eq!(arr.packed_window(1, 0, 8), arr.peek_word(1, 0));
        assert_eq!(arr.packed_window(1, 24, 32), arr.peek_word(1, 3));
    }

    #[test]
    fn packed_window_straddles_u64_boundaries() {
        let mut cfg = SimConfig::square(128, SensingScheme::Current);
        cfg.word_bits = 32;
        let mut arr = FefetArray::new(&cfg);
        // set a known pattern across the 64-bit boundary of the row
        for (i, c) in (48..80).enumerate() {
            arr.write_bit(2, c, i % 3 == 0);
        }
        let got = arr.packed_window(2, 48, 80);
        let mut want = 0u64;
        for i in 0..32 {
            if i % 3 == 0 {
                want |= 1 << i;
            }
        }
        assert_eq!(got, want);
        // full-width window with offset 0
        assert_eq!(arr.packed_window(2, 64, 128) & 0xFFFF, arr.packed_window(2, 64, 80));
        assert_eq!(arr.shadow_row(2).len(), 2);
    }

    /// Regression for the shift-overflow hazard in the packed extraction:
    /// full 64-bit windows (aligned, straddling, and at the row tail)
    /// must round-trip exactly — `1u64 << 64` would panic in debug and
    /// silently corrupt in release.
    #[test]
    fn packed_window_full_width_and_boundaries() {
        let mut cfg = SimConfig::square(128, SensingScheme::Current);
        cfg.word_bits = 64;
        let mut arr = FefetArray::new(&cfg);
        let pat_a: u64 = 0xDEAD_BEEF_0123_4567;
        let pat_b: u64 = 0xFEDC_BA98_7654_3210;
        for i in 0..64 {
            arr.write_bit(1, i, (pat_a >> i) & 1 == 1);
            arr.write_bit(1, 64 + i, (pat_b >> i) & 1 == 1);
        }
        // aligned full-width windows
        assert_eq!(arr.packed_window(1, 0, 64), pat_a);
        assert_eq!(arr.packed_window(1, 64, 128), pat_b);
        // full-width window straddling the u64 boundary
        let want = (pat_a >> 32) | (pat_b << 32);
        assert_eq!(arr.packed_window(1, 32, 96), want);
        // one-past-boundary single columns
        assert_eq!(arr.packed_window(1, 63, 64), (pat_a >> 63) & 1);
        assert_eq!(arr.packed_window(1, 64, 65), pat_b & 1);
        // width-64 window via the raw plane helper too
        assert_eq!(plane_window(arr.shadow_row(1), 32, 64), want);
        assert_eq!(width_mask(64), u64::MAX);
        assert_eq!(width_mask(1), 1);
    }

    #[test]
    fn mask_plane_all_ones_without_variation() {
        let arr = FefetArray::new(&small_cfg());
        assert!(!arr.has_mask(), "no plane needed without variation");
        assert_eq!(arr.mask_window(0, 0, 64), u64::MAX);
        assert_eq!(arr.mask_window(3, 5, 13), 0xFF);
        assert_eq!(arr.deterministic_fraction(), 1.0);
    }

    #[test]
    fn mask_policy_off_classifies_nothing() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        cfg.mask_policy = crate::config::MaskPolicy::Off;
        let arr = FefetArray::new(&cfg);
        assert!(!arr.has_mask());
        assert_eq!(arr.mask_window(0, 0, 64), 0);
        assert_eq!(arr.deterministic_fraction(), 0.0);
    }

    #[test]
    fn mask_plane_matches_per_cell_classification() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        cfg.mask_policy = crate::config::MaskPolicy::Construction;
        let arr = FefetArray::new(&cfg);
        assert!(arr.has_mask());
        let b = DvtBudget::derive(&cfg);
        for r in 0..cfg.rows {
            for c in 0..cfg.cols {
                let want = arr.dvt(r, c).abs() <= b.sym();
                let got = arr.mask_window(r, c, c + 1) & 1 == 1;
                assert_eq!(got, want, "row {r} col {c} dvt {}", arr.dvt(r, c));
            }
        }
        let f = arr.deterministic_fraction();
        assert!(f > 0.9 && f < 1.0, "sigma=20mV current sensing: {f}");
    }

    #[test]
    fn write_policy_reclassifies_on_rewrite() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        cfg.mask_policy = crate::config::MaskPolicy::Write;
        let mut arr = FefetArray::new(&cfg);
        let b = DvtBudget::derive(&cfg);
        // initial classification is against the stores-0 budget
        for c in 0..cfg.cols {
            let want = b.classify(arr.dvt(2, c), false);
            assert_eq!(arr.mask_window(2, c, c + 1) & 1 == 1, want, "col {c}");
        }
        // every rewrite re-derives the bit for the stored value
        for c in 0..cfg.cols {
            arr.write_bit(2, c, true);
            let want = b.classify(arr.dvt(2, c), true);
            assert_eq!(arr.mask_window(2, c, c + 1) & 1 == 1, want, "col {c} after SET");
            arr.write_bit(2, c, false);
            let want = b.classify(arr.dvt(2, c), false);
            assert_eq!(arr.mask_window(2, c, c + 1) & 1 == 1, want, "col {c} after RESET");
        }
    }

    #[test]
    fn planes_into_matches_planes() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        let mut arr = FefetArray::new(&cfg);
        arr.write_word(0, 1, 0x5A);
        let (pa, pb, da, db) = arr.planes(0, 1, 4, 20);
        let (mut qa, mut qb, mut ea, mut eb) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        arr.planes_into(0, 1, 4, 20, &mut qa, &mut qb, &mut ea, &mut eb);
        assert_eq!(pa, qa);
        assert_eq!(pb, qb);
        assert_eq!(da, ea);
        assert_eq!(db, eb);
    }

    #[test]
    fn state_digest_is_order_independent_and_content_sensitive() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        cfg.mask_policy = crate::config::MaskPolicy::Write;
        let mut a = FefetArray::new(&cfg);
        let mut b = FefetArray::new(&cfg);
        assert_eq!(a.state_digest(), b.state_digest(), "fresh arrays identical");

        // same final contents via different write orders (including
        // overwritten intermediates) -> identical digest
        a.write_word(1, 0, 0x5A);
        a.write_word(2, 3, 0xC3);
        b.write_word(2, 3, 0x11); // overwritten below
        b.write_word(1, 0, 0x5A);
        b.write_word(2, 3, 0xC3);
        assert_eq!(a.state_digest(), b.state_digest(), "order/history independent");

        // any single-bit content change must move the digest
        let before = a.state_digest();
        a.write_bit(5, 7, true);
        assert_ne!(a.state_digest(), before);
    }

    #[test]
    #[should_panic(expected = "distinct rows")]
    fn same_row_dual_activation_panics() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(1, 1, 0, 8, p.v_gread1, p.v_gread2);
    }
}
