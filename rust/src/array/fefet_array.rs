//! The 1T-FeFET array: rows x cols of polarization state with a digital
//! bit view, per-cell V_T variation, word-level accessors, and access
//! statistics (including half-select counts for the Fig. 5(b) analysis).

use crate::config::{DeviceParams, SimConfig};
use crate::device;
use crate::util::rng::Rng;

/// Access/energy-relevant event counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArrayStats {
    pub writes: u64,
    pub reads: u64,
    pub dual_activations: u64,
    /// Column accesses on words NOT selected by the operation but sharing
    /// the asserted wordline(s) — the pseudo-CiM columns of scheme 1.
    pub half_selected_cols: u64,
    /// Dual activations served by the bit-packed digital tier (a subset
    /// of `dual_activations`; the modeled cost is charged identically).
    pub digital_activations: u64,
    /// Sampled digital-vs-analog cross-validation checks run.
    pub xval_checks: u64,
    /// Cross-validation checks whose digital decisions diverged from the
    /// analog pipeline (must stay 0 on a calibrated configuration).
    pub xval_mismatches: u64,
}

impl ArrayStats {
    /// Field-wise sum — used when aggregating stats across engines or
    /// shards.
    pub fn merged(&self, other: &ArrayStats) -> ArrayStats {
        ArrayStats {
            writes: self.writes + other.writes,
            reads: self.reads + other.reads,
            dual_activations: self.dual_activations + other.dual_activations,
            half_selected_cols: self.half_selected_cols + other.half_selected_cols,
            digital_activations: self.digital_activations + other.digital_activations,
            xval_checks: self.xval_checks + other.xval_checks,
            xval_mismatches: self.xval_mismatches + other.xval_mismatches,
        }
    }
}

/// Bit-accurate FeFET array with analog polarization state.
pub struct FefetArray {
    params: DeviceParams,
    rows: usize,
    cols: usize,
    word_bits: usize,
    /// Row-major polarization (C/m^2).
    pol: Vec<f64>,
    /// Per-cell V_T variation offsets (volts); zeros unless vt_sigma > 0.
    dvt: Vec<f64>,
    /// Bit-packed digital shadow of `pol` (one u64 per 64 columns per
    /// row, LSB = lowest column), kept coherent on every write/reset.
    /// This is the substrate of the `FidelityTier::Digital` fast path.
    shadow: Vec<u64>,
    /// u64 words per row in `shadow`.
    shadow_stride: usize,
    stats: ArrayStats,
}

impl FefetArray {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.rows * cfg.cols;
        let dvt = if cfg.vt_sigma > 0.0 {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed_d117);
            (0..n).map(|_| rng.normal() * cfg.vt_sigma).collect()
        } else {
            vec![0.0; n]
        };
        let shadow_stride = (cfg.cols + 63) / 64;
        Self {
            params: cfg.device.clone(),
            rows: cfg.rows,
            cols: cfg.cols,
            word_bits: cfg.word_bits,
            // unwritten cells hold -P (HRS, '0') after a FLASH-like global
            // reset (paper §II.B); the shadow plane starts all-zero to
            // match
            pol: vec![cfg.device.pol_of_bit(false); n],
            dvt,
            shadow: vec![0u64; cfg.rows * shadow_stride],
            shadow_stride,
            stats: ArrayStats::default(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn word_bits(&self) -> usize {
        self.word_bits
    }

    pub fn words_per_row(&self) -> usize {
        self.cols / self.word_bits
    }

    pub fn params(&self) -> &DeviceParams {
        &self.params
    }

    pub fn stats(&self) -> ArrayStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ArrayStats::default();
    }

    /// Mutable stats access — used by engines that evaluate the analog
    /// path through an external backend (PJRT) and account the array
    /// activation themselves.
    pub fn stats_mut(&mut self) -> &mut ArrayStats {
        &mut self.stats
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Stored polarization of a cell.
    pub fn pol(&self, row: usize, col: usize) -> f64 {
        self.pol[self.idx(row, col)]
    }

    /// V_T variation offset of a cell.
    pub fn dvt(&self, row: usize, col: usize) -> f64 {
        self.dvt[self.idx(row, col)]
    }

    /// Digital view: does the cell store '1' (positive polarization)?
    pub fn bit(&self, row: usize, col: usize) -> bool {
        self.pol[self.idx(row, col)] > 0.0
    }

    /// Write one bit (behavioral SET/RESET; counts one write access).
    /// Keeps the digital shadow plane coherent with the analog state.
    pub fn write_bit(&mut self, row: usize, col: usize, bit: bool) {
        let i = self.idx(row, col);
        self.pol[i] = device::write_bit(&self.params, bit);
        let w = row * self.shadow_stride + col / 64;
        let m = 1u64 << (col % 64);
        if bit {
            self.shadow[w] |= m;
        } else {
            self.shadow[w] &= !m;
        }
        self.stats.writes += 1;
    }

    /// Write an n-bit word at `word_idx` within `row`, LSB at the lowest
    /// column (column = word_idx * word_bits + bit_position).
    pub fn write_word(&mut self, row: usize, word_idx: usize, value: u64) {
        assert!(word_idx < self.words_per_row(), "word index out of range");
        let base = word_idx * self.word_bits;
        for b in 0..self.word_bits {
            self.write_bit(row, base + b, (value >> b) & 1 == 1);
        }
        // word write is one array access regardless of width
        self.stats.writes = self.stats.writes - self.word_bits as u64 + 1;
    }

    /// Digital word view (no analog access, no stats).
    pub fn peek_word(&self, row: usize, word_idx: usize) -> u64 {
        let base = word_idx * self.word_bits;
        let mut v = 0u64;
        for b in 0..self.word_bits {
            if self.bit(row, base + b) {
                v |= 1 << b;
            }
        }
        v
    }

    /// Single-row read of the column range `[col_lo, col_hi)`: per-column
    /// cell currents at the read operating point.  Counts a read access.
    pub fn read_currents(&mut self, row: usize, col_lo: usize, col_hi: usize, vg: f64) -> Vec<f64> {
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.reads += 1;
        (col_lo..col_hi)
            .map(|c| {
                let i = self.idx(row, c);
                device::cell_current(&self.params, vg, self.params.v_read, self.pol[i], self.dvt[i])
            })
            .collect()
    }

    /// ADRA dual-row activation over `[col_lo, col_hi)`: per-column
    /// senseline currents with row_a at `vg1` and row_b at `vg2`.
    ///
    /// Because the wordlines span the whole row, all other columns are
    /// half-selected; the count is recorded for the scheme-1 pseudo-CiM
    /// energy accounting (Fig. 5(b)).
    pub fn dual_row_currents(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        assert!(row_a != row_b, "dual activation needs distinct rows");
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::senseline_current(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Dual-row discharge transients (voltage sensing) over the column
    /// range; `c_rbl` is the per-column bitline capacitance.
    pub fn dual_row_transients(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<device::RblTransient> {
        assert!(row_a != row_b);
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::rbl_transient(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    c_rbl,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Raw planes for a row pair + column range, in the layout the AOT
    /// `dc_isl` / `transient_cim` artifacts take (used by the PJRT path).
    pub fn planes(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut pol_a = Vec::new();
        let mut pol_b = Vec::new();
        let mut dvt_a = Vec::new();
        let mut dvt_b = Vec::new();
        self.planes_into(
            row_a, row_b, col_lo, col_hi, &mut pol_a, &mut pol_b, &mut dvt_a, &mut dvt_b,
        );
        (pol_a, pol_b, dvt_a, dvt_b)
    }

    /// `planes`, but writing into caller-owned buffers (cleared first) —
    /// the zero-allocation analog hot path reuses engine scratch here.
    #[allow(clippy::too_many_arguments)]
    pub fn planes_into(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        pol_a: &mut Vec<f32>,
        pol_b: &mut Vec<f32>,
        dvt_a: &mut Vec<f32>,
        dvt_b: &mut Vec<f32>,
    ) {
        pol_a.clear();
        pol_b.clear();
        dvt_a.clear();
        dvt_b.clear();
        for c in col_lo..col_hi {
            let ia = self.idx(row_a, c);
            let ib = self.idx(row_b, c);
            pol_a.push(self.pol[ia] as f32);
            pol_b.push(self.pol[ib] as f32);
            dvt_a.push(self.dvt[ia] as f32);
            dvt_b.push(self.dvt[ib] as f32);
        }
    }

    /// Bit-packed view of the column window `[col_lo, col_hi)` of a row
    /// (at most 64 columns, LSB = `col_lo`), straight from the shadow
    /// plane — no analog access, no stats.
    pub fn packed_window(&self, row: usize, col_lo: usize, col_hi: usize) -> u64 {
        debug_assert!(col_lo < col_hi && col_hi <= self.cols);
        debug_assert!(col_hi - col_lo <= 64);
        let base = row * self.shadow_stride;
        let w0 = col_lo / 64;
        let off = col_lo % 64;
        let n = col_hi - col_lo;
        let mut v = self.shadow[base + w0] >> off;
        if off != 0 && off + n > 64 {
            v |= self.shadow[base + w0 + 1] << (64 - off);
        }
        if n < 64 {
            v &= (1u64 << n) - 1;
        }
        v
    }

    /// The whole shadow row (one u64 per 64 columns, LSB-first).
    pub fn shadow_row(&self, row: usize) -> &[u64] {
        let base = row * self.shadow_stride;
        &self.shadow[base..base + self.shadow_stride]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg
    }

    #[test]
    fn fresh_array_is_all_zeros() {
        let arr = FefetArray::new(&small_cfg());
        for r in 0..4 {
            for c in 0..8 {
                assert!(!arr.bit(r, c));
            }
        }
    }

    #[test]
    fn word_write_read_roundtrip() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(3, 2, 0xA5);
        assert_eq!(arr.peek_word(3, 2), 0xA5);
        assert_eq!(arr.peek_word(3, 1), 0); // neighbors untouched
        assert_eq!(arr.peek_word(3, 3), 0);
    }

    #[test]
    fn word_write_masks_to_width() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0x1FF); // 9 bits into an 8-bit word
        assert_eq!(arr.peek_word(0, 0), 0xFF);
    }

    #[test]
    fn dual_row_currents_reflect_bits() {
        let p = DeviceParams::default();
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0b01); // A: bit0=1
        arr.write_word(1, 0, 0b10); // B: bit1=1
        let isl = arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        let levels = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        let tol = 1e-9;
        assert!((isl[0] - levels[0b10]).abs() < tol); // A=1,B=0
        assert!((isl[1] - levels[0b01]).abs() < tol); // A=0,B=1
        assert!((isl[2] - levels[0b00]).abs() < tol); // A=0,B=0
    }

    #[test]
    fn half_select_accounting() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64);
        arr.dual_row_currents(0, 1, 0, 64, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64); // full row adds 0
    }

    #[test]
    fn variation_plane_statistics() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let arr = FefetArray::new(&cfg);
        let n = (cfg.rows * cfg.cols) as f64;
        let mean: f64 = (0..cfg.rows)
            .flat_map(|r| (0..cfg.cols).map(move |c| (r, c)))
            .map(|(r, c)| arr.dvt(r, c))
            .sum::<f64>()
            / n;
        assert!(mean.abs() < 0.005, "mean {mean}");
        assert!(arr.dvt(0, 0) != arr.dvt(0, 1) || arr.dvt(1, 0) != arr.dvt(1, 1));
    }

    #[test]
    fn deterministic_variation_given_seed() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let a = FefetArray::new(&cfg);
        let b = FefetArray::new(&cfg);
        assert_eq!(a.dvt(5, 5), b.dvt(5, 5));
    }

    #[test]
    fn planes_layout_matches_state() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(2, 0, 0xFF);
        let (pa, pb, da, _db) = arr.planes(2, 3, 0, 8);
        assert_eq!(pa.len(), 8);
        assert!(pa.iter().all(|&x| x > 0.0)); // row 2 all ones
        assert!(pb.iter().all(|&x| x < 0.0)); // row 3 all zeros
        assert!(da.iter().all(|&x| x == 0.0)); // no variation configured
    }

    #[test]
    fn shadow_plane_coherent_with_bits() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(1, 0, 0xA5);
        arr.write_word(1, 3, 0x3C);
        arr.write_bit(1, 40, true);
        arr.write_bit(1, 40, false); // reset must clear the shadow too
        for c in 0..64 {
            let from_shadow = (arr.packed_window(1, c, c + 1)) & 1 == 1;
            assert_eq!(from_shadow, arr.bit(1, c), "col {c}");
        }
        // packed word view matches the digital word view
        assert_eq!(arr.packed_window(1, 0, 8), arr.peek_word(1, 0));
        assert_eq!(arr.packed_window(1, 24, 32), arr.peek_word(1, 3));
    }

    #[test]
    fn packed_window_straddles_u64_boundaries() {
        let mut cfg = SimConfig::square(128, SensingScheme::Current);
        cfg.word_bits = 32;
        let mut arr = FefetArray::new(&cfg);
        // set a known pattern across the 64-bit boundary of the row
        for (i, c) in (48..80).enumerate() {
            arr.write_bit(2, c, i % 3 == 0);
        }
        let got = arr.packed_window(2, 48, 80);
        let mut want = 0u64;
        for i in 0..32 {
            if i % 3 == 0 {
                want |= 1 << i;
            }
        }
        assert_eq!(got, want);
        // full-width window with offset 0
        assert_eq!(arr.packed_window(2, 64, 128) & 0xFFFF, arr.packed_window(2, 64, 80));
        assert_eq!(arr.shadow_row(2).len(), 2);
    }

    #[test]
    fn planes_into_matches_planes() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.02;
        let mut arr = FefetArray::new(&cfg);
        arr.write_word(0, 1, 0x5A);
        let (pa, pb, da, db) = arr.planes(0, 1, 4, 20);
        let (mut qa, mut qb, mut ea, mut eb) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        arr.planes_into(0, 1, 4, 20, &mut qa, &mut qb, &mut ea, &mut eb);
        assert_eq!(pa, qa);
        assert_eq!(pb, qb);
        assert_eq!(da, ea);
        assert_eq!(db, eb);
    }

    #[test]
    #[should_panic(expected = "distinct rows")]
    fn same_row_dual_activation_panics() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(1, 1, 0, 8, p.v_gread1, p.v_gread2);
    }
}
