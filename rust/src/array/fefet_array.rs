//! The 1T-FeFET array: rows x cols of polarization state with a digital
//! bit view, per-cell V_T variation, word-level accessors, and access
//! statistics (including half-select counts for the Fig. 5(b) analysis).

use crate::config::{DeviceParams, SimConfig};
use crate::device;
use crate::util::rng::Rng;

/// Access/energy-relevant event counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArrayStats {
    pub writes: u64,
    pub reads: u64,
    pub dual_activations: u64,
    /// Column accesses on words NOT selected by the operation but sharing
    /// the asserted wordline(s) — the pseudo-CiM columns of scheme 1.
    pub half_selected_cols: u64,
}

/// Bit-accurate FeFET array with analog polarization state.
pub struct FefetArray {
    params: DeviceParams,
    rows: usize,
    cols: usize,
    word_bits: usize,
    /// Row-major polarization (C/m^2).
    pol: Vec<f64>,
    /// Per-cell V_T variation offsets (volts); zeros unless vt_sigma > 0.
    dvt: Vec<f64>,
    stats: ArrayStats,
}

impl FefetArray {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.rows * cfg.cols;
        let dvt = if cfg.vt_sigma > 0.0 {
            let mut rng = Rng::new(cfg.seed ^ 0x5eed_d117);
            (0..n).map(|_| rng.normal() * cfg.vt_sigma).collect()
        } else {
            vec![0.0; n]
        };
        Self {
            params: cfg.device.clone(),
            rows: cfg.rows,
            cols: cfg.cols,
            word_bits: cfg.word_bits,
            // unwritten cells hold -P (HRS, '0') after a FLASH-like global
            // reset (paper §II.B)
            pol: vec![cfg.device.pol_of_bit(false); n],
            dvt,
            stats: ArrayStats::default(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn word_bits(&self) -> usize {
        self.word_bits
    }

    pub fn words_per_row(&self) -> usize {
        self.cols / self.word_bits
    }

    pub fn params(&self) -> &DeviceParams {
        &self.params
    }

    pub fn stats(&self) -> ArrayStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ArrayStats::default();
    }

    /// Mutable stats access — used by engines that evaluate the analog
    /// path through an external backend (PJRT) and account the array
    /// activation themselves.
    pub fn stats_mut(&mut self) -> &mut ArrayStats {
        &mut self.stats
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Stored polarization of a cell.
    pub fn pol(&self, row: usize, col: usize) -> f64 {
        self.pol[self.idx(row, col)]
    }

    /// V_T variation offset of a cell.
    pub fn dvt(&self, row: usize, col: usize) -> f64 {
        self.dvt[self.idx(row, col)]
    }

    /// Digital view: does the cell store '1' (positive polarization)?
    pub fn bit(&self, row: usize, col: usize) -> bool {
        self.pol[self.idx(row, col)] > 0.0
    }

    /// Write one bit (behavioral SET/RESET; counts one write access).
    pub fn write_bit(&mut self, row: usize, col: usize, bit: bool) {
        let i = self.idx(row, col);
        self.pol[i] = device::write_bit(&self.params, bit);
        self.stats.writes += 1;
    }

    /// Write an n-bit word at `word_idx` within `row`, LSB at the lowest
    /// column (column = word_idx * word_bits + bit_position).
    pub fn write_word(&mut self, row: usize, word_idx: usize, value: u64) {
        assert!(word_idx < self.words_per_row(), "word index out of range");
        let base = word_idx * self.word_bits;
        for b in 0..self.word_bits {
            self.write_bit(row, base + b, (value >> b) & 1 == 1);
        }
        // word write is one array access regardless of width
        self.stats.writes = self.stats.writes - self.word_bits as u64 + 1;
    }

    /// Digital word view (no analog access, no stats).
    pub fn peek_word(&self, row: usize, word_idx: usize) -> u64 {
        let base = word_idx * self.word_bits;
        let mut v = 0u64;
        for b in 0..self.word_bits {
            if self.bit(row, base + b) {
                v |= 1 << b;
            }
        }
        v
    }

    /// Single-row read of the column range `[col_lo, col_hi)`: per-column
    /// cell currents at the read operating point.  Counts a read access.
    pub fn read_currents(&mut self, row: usize, col_lo: usize, col_hi: usize, vg: f64) -> Vec<f64> {
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.reads += 1;
        (col_lo..col_hi)
            .map(|c| {
                let i = self.idx(row, c);
                device::cell_current(&self.params, vg, self.params.v_read, self.pol[i], self.dvt[i])
            })
            .collect()
    }

    /// ADRA dual-row activation over `[col_lo, col_hi)`: per-column
    /// senseline currents with row_a at `vg1` and row_b at `vg2`.
    ///
    /// Because the wordlines span the whole row, all other columns are
    /// half-selected; the count is recorded for the scheme-1 pseudo-CiM
    /// energy accounting (Fig. 5(b)).
    pub fn dual_row_currents(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
    ) -> Vec<f64> {
        assert!(row_a != row_b, "dual activation needs distinct rows");
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::senseline_current(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Dual-row discharge transients (voltage sensing) over the column
    /// range; `c_rbl` is the per-column bitline capacitance.
    pub fn dual_row_transients(
        &mut self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
        vg1: f64,
        vg2: f64,
        c_rbl: f64,
    ) -> Vec<device::RblTransient> {
        assert!(row_a != row_b);
        assert!(col_lo < col_hi && col_hi <= self.cols);
        self.stats.dual_activations += 1;
        self.stats.half_selected_cols += (self.cols - (col_hi - col_lo)) as u64;
        (col_lo..col_hi)
            .map(|c| {
                let ia = self.idx(row_a, c);
                let ib = self.idx(row_b, c);
                device::rbl_transient(
                    &self.params,
                    self.pol[ia],
                    self.pol[ib],
                    vg1,
                    vg2,
                    self.params.v_read,
                    c_rbl,
                    self.dvt[ia],
                    self.dvt[ib],
                )
            })
            .collect()
    }

    /// Raw planes for a row pair + column range, in the layout the AOT
    /// `dc_isl` / `transient_cim` artifacts take (used by the PJRT path).
    pub fn planes(
        &self,
        row_a: usize,
        row_b: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let take = |row: usize, f: &dyn Fn(usize) -> f64| -> Vec<f32> {
            (col_lo..col_hi)
                .map(|c| f(self.idx(row, c)) as f32)
                .collect()
        };
        (
            take(row_a, &|i| self.pol[i]),
            take(row_b, &|i| self.pol[i]),
            take(row_a, &|i| self.dvt[i]),
            take(row_b, &|i| self.dvt[i]),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        cfg
    }

    #[test]
    fn fresh_array_is_all_zeros() {
        let arr = FefetArray::new(&small_cfg());
        for r in 0..4 {
            for c in 0..8 {
                assert!(!arr.bit(r, c));
            }
        }
    }

    #[test]
    fn word_write_read_roundtrip() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(3, 2, 0xA5);
        assert_eq!(arr.peek_word(3, 2), 0xA5);
        assert_eq!(arr.peek_word(3, 1), 0); // neighbors untouched
        assert_eq!(arr.peek_word(3, 3), 0);
    }

    #[test]
    fn word_write_masks_to_width() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0x1FF); // 9 bits into an 8-bit word
        assert_eq!(arr.peek_word(0, 0), 0xFF);
    }

    #[test]
    fn dual_row_currents_reflect_bits() {
        let p = DeviceParams::default();
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(0, 0, 0b01); // A: bit0=1
        arr.write_word(1, 0, 0b10); // B: bit1=1
        let isl = arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        let levels = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        let tol = 1e-9;
        assert!((isl[0] - levels[0b10]).abs() < tol); // A=1,B=0
        assert!((isl[1] - levels[0b01]).abs() < tol); // A=0,B=1
        assert!((isl[2] - levels[0b00]).abs() < tol); // A=0,B=0
    }

    #[test]
    fn half_select_accounting() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(0, 1, 0, 8, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64);
        arr.dual_row_currents(0, 1, 0, 64, p.v_gread1, p.v_gread2);
        assert_eq!(arr.stats().half_selected_cols, (64 - 8) as u64); // full row adds 0
    }

    #[test]
    fn variation_plane_statistics() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let arr = FefetArray::new(&cfg);
        let n = (cfg.rows * cfg.cols) as f64;
        let mean: f64 = (0..cfg.rows)
            .flat_map(|r| (0..cfg.cols).map(move |c| (r, c)))
            .map(|(r, c)| arr.dvt(r, c))
            .sum::<f64>()
            / n;
        assert!(mean.abs() < 0.005, "mean {mean}");
        assert!(arr.dvt(0, 0) != arr.dvt(0, 1) || arr.dvt(1, 0) != arr.dvt(1, 1));
    }

    #[test]
    fn deterministic_variation_given_seed() {
        let mut cfg = small_cfg();
        cfg.vt_sigma = 0.03;
        let a = FefetArray::new(&cfg);
        let b = FefetArray::new(&cfg);
        assert_eq!(a.dvt(5, 5), b.dvt(5, 5));
    }

    #[test]
    fn planes_layout_matches_state() {
        let mut arr = FefetArray::new(&small_cfg());
        arr.write_word(2, 0, 0xFF);
        let (pa, pb, da, _db) = arr.planes(2, 3, 0, 8);
        assert_eq!(pa.len(), 8);
        assert!(pa.iter().all(|&x| x > 0.0)); // row 2 all ones
        assert!(pb.iter().all(|&x| x < 0.0)); // row 3 all zeros
        assert!(da.iter().all(|&x| x == 0.0)); // no variation configured
    }

    #[test]
    #[should_panic(expected = "distinct rows")]
    fn same_row_dual_activation_panics() {
        let mut arr = FefetArray::new(&small_cfg());
        let p = DeviceParams::default();
        arr.dual_row_currents(1, 1, 0, 8, p.v_gread1, p.v_gread2);
    }
}
