//! Array write schemes (paper §II.B): "two-phase write" and "FLASH-like
//! global reset + selective set", with access/energy accounting.
//!
//! * Two-phase: per row, phase 1 RESETs the cells that must become '0',
//!   phase 2 SETs the cells that must become '1' (2 row operations per
//!   written row, no disturb to other rows).
//! * FLASH-like: one global reset pulse clears the whole array (or a row
//!   block) to '0', then one selective-set pass per row writes the '1's.
//!   Cheaper for bulk loads, destructive for everything else in the block.

use super::fefet_array::FefetArray;
use crate::energy::constants::T_WRITE;

/// Which write discipline to use for a bulk load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteScheme {
    TwoPhase,
    FlashLike,
}

/// Accounting of a bulk write.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WriteReport {
    /// Row-level write pulses issued.
    pub row_pulses: u64,
    /// Individual cells whose polarization was switched.
    pub cells_switched: u64,
    /// Total write latency (pulses are serialized per bank), seconds.
    pub latency: f64,
}

/// Bulk-load `rows_data` (one u64-per-word row image) starting at
/// `row_lo`, using the given scheme.  Returns the accounting report.
pub fn bulk_write(
    array: &mut FefetArray,
    row_lo: usize,
    rows_data: &[Vec<u64>],
    scheme: WriteScheme,
) -> WriteReport {
    let words = array.words_per_row();
    let mut rep = WriteReport::default();
    match scheme {
        WriteScheme::TwoPhase => {
            for (i, row_img) in rows_data.iter().enumerate() {
                assert!(row_img.len() <= words);
                let row = row_lo + i;
                // phase 1: reset cells that must be 0; phase 2: set the 1s
                for phase_bit in [false, true] {
                    let mut any = false;
                    for (w, &val) in row_img.iter().enumerate() {
                        for b in 0..array.word_bits() {
                            let col = w * array.word_bits() + b;
                            let want = (val >> b) & 1 == 1;
                            if want == phase_bit && array.bit(row, col) != want {
                                array.write_bit(row, col, want);
                                rep.cells_switched += 1;
                                any = true;
                            }
                        }
                    }
                    if any {
                        rep.row_pulses += 1;
                        rep.latency += T_WRITE;
                    }
                }
            }
        }
        WriteScheme::FlashLike => {
            // one global reset pulse over the target rows
            rep.row_pulses += 1;
            rep.latency += T_WRITE;
            for (i, row_img) in rows_data.iter().enumerate() {
                let row = row_lo + i;
                for w in 0..words {
                    for b in 0..array.word_bits() {
                        let col = w * array.word_bits() + b;
                        if array.bit(row, col) {
                            array.write_bit(row, col, false);
                            rep.cells_switched += 1;
                        }
                    }
                }
                let _ = row_img;
            }
            // selective set pass per row
            for (i, row_img) in rows_data.iter().enumerate() {
                let row = row_lo + i;
                let mut any = false;
                for (w, &val) in row_img.iter().enumerate() {
                    for b in 0..array.word_bits() {
                        if (val >> b) & 1 == 1 {
                            let col = w * array.word_bits() + b;
                            array.write_bit(row, col, true);
                            rep.cells_switched += 1;
                            any = true;
                        }
                    }
                }
                if any {
                    rep.row_pulses += 1;
                    rep.latency += T_WRITE;
                }
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::util::rng::Rng;

    fn array() -> FefetArray {
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        FefetArray::new(&cfg)
    }

    fn random_image(rng: &mut Rng, rows: usize, words: usize) -> Vec<Vec<u64>> {
        (0..rows)
            .map(|_| (0..words).map(|_| rng.below(256)).collect())
            .collect()
    }

    #[test]
    fn both_schemes_produce_identical_final_state() {
        let mut rng = Rng::new(42);
        let img = random_image(&mut rng, 4, 8);
        let mut a1 = array();
        let mut a2 = array();
        bulk_write(&mut a1, 0, &img, WriteScheme::TwoPhase);
        bulk_write(&mut a2, 0, &img, WriteScheme::FlashLike);
        for r in 0..4 {
            for w in 0..8 {
                assert_eq!(a1.peek_word(r, w), img[r][w]);
                assert_eq!(a2.peek_word(r, w), img[r][w]);
            }
        }
    }

    #[test]
    fn flash_like_uses_fewer_pulses_for_bulk_loads() {
        let mut rng = Rng::new(43);
        // overwrite EXISTING data (a fresh array is all-zeros, which makes
        // two-phase degenerate-cheap: its reset phase is free)
        let old = random_image(&mut rng, 16, 8);
        let img = random_image(&mut rng, 16, 8);
        let mut a1 = array();
        let mut a2 = array();
        bulk_write(&mut a1, 0, &old, WriteScheme::TwoPhase);
        bulk_write(&mut a2, 0, &old, WriteScheme::TwoPhase);
        let two = bulk_write(&mut a1, 0, &img, WriteScheme::TwoPhase);
        let flash = bulk_write(&mut a2, 0, &img, WriteScheme::FlashLike);
        assert!(
            flash.row_pulses < two.row_pulses,
            "flash {} vs two-phase {}",
            flash.row_pulses,
            two.row_pulses
        );
        assert!(flash.latency < two.latency);
    }

    #[test]
    fn two_phase_skips_already_correct_cells() {
        let img = vec![vec![0xFFu64; 8]];
        let mut a = array();
        let first = bulk_write(&mut a, 0, &img, WriteScheme::TwoPhase);
        assert!(first.cells_switched > 0);
        // writing the same image again switches nothing
        let second = bulk_write(&mut a, 0, &img, WriteScheme::TwoPhase);
        assert_eq!(second.cells_switched, 0);
        assert_eq!(second.row_pulses, 0);
    }

    #[test]
    fn writes_do_not_touch_other_rows() {
        let img = vec![vec![0xAAu64; 8]];
        let mut a = array();
        a.write_word(10, 0, 0x55);
        bulk_write(&mut a, 0, &img, WriteScheme::FlashLike);
        assert_eq!(a.peek_word(10, 0), 0x55, "bystander row was disturbed");
    }
}
