//! 1T-FeFET memory array: bit-accurate state, polarization planes,
//! per-cell V_T variation, write/read biasing, and half-select accounting.

pub mod biasing;
pub mod endurance;
pub mod fefet_array;
pub mod write_scheme;

pub use biasing::{BiasMode, RowBias};
pub use endurance::{WearLeveler, WearTracker};
pub use fefet_array::{plane_set_bit, plane_window, width_mask, ArrayStats, FefetArray};
pub use write_scheme::{bulk_write, WriteReport, WriteScheme};
