//! Wordline biasing schemes: the single-row read, the symmetric dual-row
//! activation of prior CiM work (Fig. 1), and ADRA's asymmetric dual-row
//! activation (Fig. 3).

use crate::config::DeviceParams;

/// Voltage assignment to the selected wordline(s) for one operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowBias {
    /// WL voltage of the row holding word A (or the only row for reads).
    pub vg_row_a: f64,
    /// WL voltage of the row holding word B (dual-row ops only).
    pub vg_row_b: Option<f64>,
}

/// How wordlines are asserted for an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BiasMode {
    /// Standard single-row read at V_GREAD.
    SingleRead,
    /// Prior-work CiM: both rows at the same V_GREAD (many-to-one mapping;
    /// only commutative functions computable).
    SymmetricDual,
    /// ADRA: WL_A at V_GREAD1 < WL_B at V_GREAD2 (one-to-one mapping).
    AsymmetricDual,
}

impl BiasMode {
    pub fn bias(&self, p: &DeviceParams) -> RowBias {
        match self {
            BiasMode::SingleRead => RowBias {
                vg_row_a: p.v_gread2,
                vg_row_b: None,
            },
            BiasMode::SymmetricDual => RowBias {
                vg_row_a: p.v_gread2,
                vg_row_b: Some(p.v_gread2),
            },
            BiasMode::AsymmetricDual => RowBias {
                vg_row_a: p.v_gread1,
                vg_row_b: Some(p.v_gread2),
            },
        }
    }

    /// Number of wordlines asserted.
    pub fn rows_active(&self) -> usize {
        match self {
            BiasMode::SingleRead => 1,
            _ => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adra_is_asymmetric() {
        let p = DeviceParams::default();
        let b = BiasMode::AsymmetricDual.bias(&p);
        assert_eq!(b.vg_row_a, p.v_gread1);
        assert_eq!(b.vg_row_b, Some(p.v_gread2));
        assert!(b.vg_row_a < b.vg_row_b.unwrap());
    }

    #[test]
    fn symmetric_matches_prior_work() {
        let p = DeviceParams::default();
        let b = BiasMode::SymmetricDual.bias(&p);
        assert_eq!(b.vg_row_a, b.vg_row_b.unwrap());
    }

    #[test]
    fn single_read_asserts_one_row() {
        let p = DeviceParams::default();
        let b = BiasMode::SingleRead.bias(&p);
        assert!(b.vg_row_b.is_none());
        assert_eq!(BiasMode::SingleRead.rows_active(), 1);
        assert_eq!(BiasMode::AsymmetricDual.rows_active(), 2);
    }
}
