//! Durable array state: checksummed snapshot + write-ahead log.
//!
//! The paper's core system advantage is FeFET non-volatility (§II.B) —
//! array state that survives power loss.  This module gives the serve
//! stack the matching software contract (ROADMAP item 5a): a
//! [`DurableStore`] owns one directory holding
//!
//! * `snapshot.bin` — the last checkpoint: the serve table's logical
//!   contents ([`TableImage`]: record slots, range versions, scratch
//!   rows, epoch), per-shard endurance counters, and the calibration
//!   store's JSON (PR 8's snapshot folded into one recovery unit), all
//!   under a single FNV-1a checksum;
//! * `snapshot.prev` — the previous checkpoint, kept as the fallback a
//!   torn or corrupted `snapshot.bin` recovers to;
//! * `wal.bin` — an append-only log of every content-changing write
//!   since the last checkpoint, one checksum per record.
//!
//! **Recovery invariant** (pinned by `tests/durability.rs`): for ANY
//! crash point — mid-WAL, mid-checkpoint rename, or a corrupted record —
//! `open` replays to a state bit-identical to a fault-free run truncated
//! at the last durable record.  Two properties make that hold:
//!
//! 1. WAL record writes carry the range VERSION assigned at write time,
//!    and replay skips records already covered by the snapshot's epoch —
//!    so the `snapshot written, WAL not yet truncated` crash window
//!    replays idempotently and versions never diverge;
//! 2. checkpoints are written to a temp file and renamed into place
//!    (old snapshot rotated to `.prev` first), and the WAL is truncated
//!    only after the rename — every crash window leaves either
//!    `snapshot.bin` + suffix WAL or `snapshot.prev` + full WAL.
//!
//! Array bit-planes are NOT serialized: `FefetArray::write_bit` is
//! deterministic (polarization, shadow plane, and margin mask are pure
//! functions of the stored bit and the seeded per-cell dVt), so
//! replaying the logical contents into a fresh array reproduces the
//! pre-crash array bit-identically — proven against pol/shadow/mask
//! digests by the crash-point sweep test.
//!
//! The corruption hooks (`faults::corrupt_wal` / `faults::corrupt_snapshot`)
//! flip bytes AFTER checksums are computed, so injected corruption is
//! always detectable; detections count into
//! `adra.store.corruptions_detected`.

use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::faults;
use crate::observe::Registry;

pub const SNAPSHOT_FILE: &str = "snapshot.bin";
pub const SNAPSHOT_PREV: &str = "snapshot.prev";
pub const WAL_FILE: &str = "wal.bin";

const MAGIC: &[u8; 8] = b"ADRASNP1";

/// FNV-1a 64-bit — the store's checksum (no external deps).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One write-ahead-log record: a content-changing write observed by the
/// serve table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalOp {
    /// A record-slot write with the version (= epoch) it was assigned.
    /// Replay applies it only when `version` exceeds the recovered
    /// snapshot's epoch, which makes replay idempotent across the
    /// checkpoint race window.
    Record { slot: u64, value: u64, version: u64 },
    /// A scratch-row broadcast (no version: scratch rows are unversioned
    /// and last-write-wins, so in-order replay converges).
    Scratch { idx: u64, value: u64 },
}

/// Serializable image of the serve table (`serve::TableState`) — the
/// logical array contents the store persists and replay rebuilds
/// physical arrays from.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TableImage {
    pub n_records: u64,
    pub word_mask: u64,
    pub epoch: u64,
    pub invalidating_writes: u64,
    pub records: Vec<Option<u64>>,
    pub versions: Vec<u64>,
    pub scratch: Vec<Option<u64>>,
}

/// Everything one checkpoint captures.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DurableState {
    pub table: TableImage,
    /// Per-shard, per-row endurance write counters
    /// (`array::WearTracker` contents).
    pub wear: Vec<Vec<u64>>,
    /// `planner::CalibrationStore::to_json` snapshot.
    pub calibration_json: String,
}

/// What `DurableStore::open` recovered from disk.
#[derive(Clone, Debug, Default)]
pub struct Recovery {
    /// Last good checkpoint (`None` on a fresh or unrecoverable store —
    /// the caller starts from its initial state).
    pub state: Option<DurableState>,
    /// WAL records that verified, in append order; apply on top of
    /// `state`.
    pub wal: Vec<WalOp>,
    /// Checksum/decode failures detected during recovery.
    pub corruptions: u64,
    /// `true` when `snapshot.bin` was bad and `.prev` was used.
    pub used_fallback: bool,
    /// Wall nanoseconds spent reading + verifying + replaying.
    pub replay_ns: u64,
}

// ---- binary codec (hand-rolled; the crate is serde-free) -------------

struct Enc(Vec<u8>);

impl Enc {
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn opt(&mut self, v: Option<u64>) {
        match v {
            None => self.u8(0),
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
        }
    }
    fn vec_u64(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x);
        }
    }
    fn vec_opt(&mut self, v: &[Option<u64>]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.opt(x);
        }
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.0.extend_from_slice(b);
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn u64(&mut self) -> Option<u64> {
        let end = self.at.checked_add(8)?;
        let b = self.buf.get(self.at..end)?;
        self.at = end;
        Some(u64::from_le_bytes(b.try_into().ok()?))
    }
    fn u8(&mut self) -> Option<u8> {
        let b = *self.buf.get(self.at)?;
        self.at += 1;
        Some(b)
    }
    fn opt(&mut self) -> Option<Option<u64>> {
        match self.u8()? {
            0 => Some(None),
            1 => Some(Some(self.u64()?)),
            _ => None,
        }
    }
    fn vec_u64(&mut self) -> Option<Vec<u64>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Some(v)
    }
    fn vec_opt(&mut self) -> Option<Vec<Option<u64>>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.opt()?);
        }
        Some(v)
    }
    fn bytes(&mut self) -> Option<&'a [u8]> {
        let n = self.len()?;
        let end = self.at.checked_add(n)?;
        let b = self.buf.get(self.at..end)?;
        self.at = end;
        Some(b)
    }
    /// A length field, sanity-bounded by the remaining buffer so corrupt
    /// lengths cannot drive huge allocations.
    fn len(&mut self) -> Option<usize> {
        let n = self.u64()? as usize;
        if n > self.buf.len().saturating_sub(self.at) {
            return None;
        }
        Some(n)
    }
}

fn encode_state(state: &DurableState) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(256));
    e.0.extend_from_slice(MAGIC);
    let t = &state.table;
    e.u64(t.n_records);
    e.u64(t.word_mask);
    e.u64(t.epoch);
    e.u64(t.invalidating_writes);
    e.vec_opt(&t.records);
    e.vec_u64(&t.versions);
    e.vec_opt(&t.scratch);
    e.u64(state.wear.len() as u64);
    for shard in &state.wear {
        e.vec_u64(shard);
    }
    e.bytes(state.calibration_json.as_bytes());
    let sum = fnv64(&e.0);
    e.u64(sum);
    e.0
}

fn decode_state(buf: &[u8]) -> Option<DurableState> {
    if buf.len() < MAGIC.len() + 8 || &buf[..MAGIC.len()] != MAGIC {
        return None;
    }
    let (payload, trailer) = buf.split_at(buf.len() - 8);
    let sum = u64::from_le_bytes(trailer.try_into().ok()?);
    if fnv64(payload) != sum {
        return None;
    }
    let mut d = Dec { buf: payload, at: MAGIC.len() };
    let table = TableImage {
        n_records: d.u64()?,
        word_mask: d.u64()?,
        epoch: d.u64()?,
        invalidating_writes: d.u64()?,
        records: d.vec_opt()?,
        versions: d.vec_u64()?,
        scratch: d.vec_opt()?,
    };
    let n_shards = d.len()?;
    let mut wear = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        wear.push(d.vec_u64()?);
    }
    let calibration_json = String::from_utf8(d.bytes()?.to_vec()).ok()?;
    Some(DurableState { table, wear, calibration_json })
}

fn encode_wal_op(op: &WalOp) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(32));
    match op {
        WalOp::Record { slot, value, version } => {
            e.u8(1);
            e.u64(*slot);
            e.u64(*value);
            e.u64(*version);
        }
        WalOp::Scratch { idx, value } => {
            e.u8(2);
            e.u64(*idx);
            e.u64(*value);
        }
    }
    e.0
}

fn decode_wal_op(body: &[u8]) -> Option<WalOp> {
    let mut d = Dec { buf: body, at: 0 };
    let op = match d.u8()? {
        1 => WalOp::Record { slot: d.u64()?, value: d.u64()?, version: d.u64()? },
        2 => WalOp::Scratch { idx: d.u64()?, value: d.u64()? },
        _ => return None,
    };
    (d.at == body.len()).then_some(op)
}

// ---- the store -------------------------------------------------------

/// Snapshot + WAL persistence over one directory.  Metric fields are
/// cumulative for this handle's lifetime and mirror into the registry as
/// the `adra.store.*` families via [`DurableStore::publish`].
pub struct DurableStore {
    dir: PathBuf,
    wal: Option<File>,
    /// WAL records appended (cumulative).
    pub wal_records: u64,
    /// Records currently in the live log (since the last checkpoint).
    pub wal_len: u64,
    /// Size of the last snapshot written or recovered, bytes.
    pub snapshot_bytes: u64,
    /// Checksum/decode failures detected (recovery + live).
    pub corruptions_detected: u64,
    /// Cumulative recovery wall time, ns.
    pub replay_ns: u64,
    /// Checkpoints written.
    pub checkpoints: u64,
}

impl DurableStore {
    /// Open (or create) a store directory and recover whatever it holds.
    pub fn open(dir: &Path) -> io::Result<(Self, Recovery)> {
        fs::create_dir_all(dir)?;
        let start = Instant::now();
        let mut rec = Recovery::default();
        let mut snapshot_bytes = 0u64;

        // last good snapshot: snapshot.bin, else snapshot.prev
        let cur = dir.join(SNAPSHOT_FILE);
        let prev = dir.join(SNAPSHOT_PREV);
        for (path, is_fallback) in [(&cur, false), (&prev, true)] {
            if let Ok(bytes) = fs::read(path) {
                match decode_state(&bytes) {
                    Some(state) => {
                        snapshot_bytes = bytes.len() as u64;
                        rec.state = Some(state);
                        rec.used_fallback = is_fallback;
                        break;
                    }
                    None => rec.corruptions += 1,
                }
            }
        }

        // WAL replay: verify record by record, stop at the first bad or
        // truncated one (a truncated tail is the normal crash artifact)
        let wal_path = dir.join(WAL_FILE);
        let mut wal_len = 0u64;
        if let Ok(bytes) = fs::read(&wal_path) {
            let mut at = 0usize;
            while at + 4 <= bytes.len() {
                let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
                let body_end = match at.checked_add(4).and_then(|s| s.checked_add(len)) {
                    Some(e) if e + 8 <= bytes.len() => e,
                    _ => break, // truncated tail
                };
                let body = &bytes[at + 4..body_end];
                let sum = u64::from_le_bytes(bytes[body_end..body_end + 8].try_into().unwrap());
                if fnv64(body) != sum {
                    rec.corruptions += 1;
                    break;
                }
                match decode_wal_op(body) {
                    Some(op) => rec.wal.push(op),
                    None => {
                        rec.corruptions += 1;
                        break;
                    }
                }
                wal_len += 1;
                at = body_end + 8;
            }
        }
        rec.replay_ns = start.elapsed().as_nanos() as u64;

        let wal = OpenOptions::new().create(true).append(true).open(&wal_path)?;
        Ok((
            Self {
                dir: dir.to_path_buf(),
                wal: Some(wal),
                wal_records: wal_len,
                wal_len,
                snapshot_bytes,
                corruptions_detected: rec.corruptions,
                replay_ns: rec.replay_ns,
                checkpoints: 0,
            },
            rec,
        ))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append records to the WAL and flush.  Each record is length-
    /// prefixed and individually checksummed; the fault hook may flip a
    /// body byte AFTER the checksum is computed (detectable corruption).
    pub fn append(&mut self, ops: &[WalOp]) -> io::Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        let mut out = Vec::with_capacity(ops.len() * 40);
        for op in ops {
            let mut body = encode_wal_op(op);
            let sum = fnv64(&body);
            if faults::active() {
                faults::corrupt_wal(&mut body);
            }
            out.extend_from_slice(&(body.len() as u32).to_le_bytes());
            out.extend_from_slice(&body);
            out.extend_from_slice(&sum.to_le_bytes());
        }
        let wal = self.wal.as_mut().expect("wal handle");
        wal.write_all(&out)?;
        wal.flush()?;
        self.wal_records += ops.len() as u64;
        self.wal_len += ops.len() as u64;
        Ok(())
    }

    /// Write a checkpoint and truncate the WAL.  Ordering: temp write →
    /// rotate old snapshot to `.prev` → rename temp into place → truncate
    /// WAL; every crash window between those steps recovers consistently
    /// (see module docs).
    pub fn checkpoint(&mut self, state: &DurableState) -> io::Result<()> {
        let mut bytes = encode_state(state);
        if faults::active() {
            faults::corrupt_snapshot(&mut bytes);
        }
        let tmp = self.dir.join("snapshot.tmp");
        fs::write(&tmp, &bytes)?;
        let cur = self.dir.join(SNAPSHOT_FILE);
        if cur.exists() {
            fs::rename(&cur, self.dir.join(SNAPSHOT_PREV))?;
        }
        fs::rename(&tmp, &cur)?;
        // WAL truncation last: until this completes, snapshot + full WAL
        // replays idempotently (version-stamped records)
        self.wal = None;
        let wal_path = self.dir.join(WAL_FILE);
        let wal = OpenOptions::new().create(true).write(true).truncate(true).open(&wal_path)?;
        drop(wal);
        self.wal = Some(OpenOptions::new().append(true).open(&wal_path)?);
        self.wal_len = 0;
        self.snapshot_bytes = bytes.len() as u64;
        self.checkpoints += 1;
        Ok(())
    }

    /// Mirror store health into the registry (the `adra.store.*`
    /// families the durability CI job and the wear/health rules read).
    pub fn publish(&self, reg: &Registry, queue: &str) {
        let l: [(&str, &str); 1] = [("queue", queue)];
        reg.counter("adra.store.wal_records", "WAL records appended.", &l)
            .set_at_least(self.wal_records);
        reg.gauge("adra.store.snapshot_bytes", "Size of the last checkpoint snapshot.", &l)
            .set(self.snapshot_bytes as f64);
        reg.counter("adra.store.replay_ns", "Cumulative recovery replay wall time (ns).", &l)
            .set_at_least(self.replay_ns);
        reg.counter(
            "adra.store.corruptions_detected",
            "Snapshot/WAL checksum or decode failures detected.",
            &l,
        )
        .set_at_least(self.corruptions_detected);
        reg.counter("adra.store.checkpoints", "Checkpoints written.", &l)
            .set_at_least(self.checkpoints);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("adra_store_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn state(epoch: u64) -> DurableState {
        DurableState {
            table: TableImage {
                n_records: 4,
                word_mask: 0xFF,
                epoch,
                invalidating_writes: epoch,
                records: vec![Some(1), None, Some(3), None],
                versions: vec![1, 0, epoch, 0],
                scratch: vec![Some(9)],
            },
            wear: vec![vec![5, 0, 2], vec![0, 0, 7]],
            calibration_json: "{\"factors\":[]}".into(),
        }
    }

    #[test]
    fn snapshot_round_trips_bit_identically() {
        let s = state(3);
        let bytes = encode_state(&s);
        assert_eq!(decode_state(&bytes), Some(s));
    }

    #[test]
    fn any_single_byte_flip_is_detected() {
        let bytes = encode_state(&state(3));
        for at in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[at] ^= 0x40;
            assert!(decode_state(&bad).is_none(), "flip at {at} undetected");
        }
    }

    #[test]
    fn open_append_checkpoint_recover() {
        let dir = tmpdir("roundtrip");
        let ops = vec![
            WalOp::Record { slot: 0, value: 7, version: 4 },
            WalOp::Scratch { idx: 1, value: 42 },
        ];
        {
            let (mut store, rec) = DurableStore::open(&dir).unwrap();
            assert!(rec.state.is_none());
            assert!(rec.wal.is_empty());
            store.checkpoint(&state(3)).unwrap();
            store.append(&ops).unwrap();
        }
        let (store, rec) = DurableStore::open(&dir).unwrap();
        assert_eq!(rec.state, Some(state(3)));
        assert_eq!(rec.wal, ops);
        assert_eq!(rec.corruptions, 0);
        assert!(!rec.used_fallback);
        assert_eq!(store.wal_len, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_wal_tail_replays_the_good_prefix() {
        let dir = tmpdir("truncate");
        {
            let (mut store, _) = DurableStore::open(&dir).unwrap();
            store
                .append(&[
                    WalOp::Record { slot: 0, value: 1, version: 1 },
                    WalOp::Record { slot: 1, value: 2, version: 2 },
                ])
                .unwrap();
        }
        // chop mid-record: drop the last 5 bytes
        let wal = dir.join(WAL_FILE);
        let bytes = fs::read(&wal).unwrap();
        fs::write(&wal, &bytes[..bytes.len() - 5]).unwrap();
        let (_, rec) = DurableStore::open(&dir).unwrap();
        assert_eq!(rec.wal, vec![WalOp::Record { slot: 0, value: 1, version: 1 }]);
        assert_eq!(rec.corruptions, 0, "a torn tail is a crash artifact, not corruption");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_wal_record_stops_replay_and_counts() {
        let dir = tmpdir("walflip");
        {
            let (mut store, _) = DurableStore::open(&dir).unwrap();
            store
                .append(&[
                    WalOp::Record { slot: 0, value: 1, version: 1 },
                    WalOp::Record { slot: 1, value: 2, version: 2 },
                    WalOp::Record { slot: 2, value: 3, version: 3 },
                ])
                .unwrap();
        }
        let wal = dir.join(WAL_FILE);
        let mut bytes = fs::read(&wal).unwrap();
        // flip a body byte of the SECOND record (each record: 4 len + 25
        // body + 8 sum = 37 bytes)
        bytes[37 + 6] ^= 0xFF;
        fs::write(&wal, &bytes).unwrap();
        let (store, rec) = DurableStore::open(&dir).unwrap();
        assert_eq!(rec.wal, vec![WalOp::Record { slot: 0, value: 1, version: 1 }]);
        assert_eq!(rec.corruptions, 1);
        assert_eq!(store.corruptions_detected, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_falls_back_to_prev() {
        let dir = tmpdir("prevfallback");
        {
            let (mut store, _) = DurableStore::open(&dir).unwrap();
            store.checkpoint(&state(1)).unwrap();
            store.checkpoint(&state(2)).unwrap(); // state(1) rotates to .prev
        }
        let cur = dir.join(SNAPSHOT_FILE);
        let mut bytes = fs::read(&cur).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&cur, &bytes).unwrap();
        let (_, rec) = DurableStore::open(&dir).unwrap();
        assert_eq!(rec.state, Some(state(1)), "fallback to last good snapshot");
        assert!(rec.used_fallback);
        assert_eq!(rec.corruptions, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    // Injected (faults::install) WAL/snapshot corruption is covered by
    // `tests/durability.rs` — the injector is process-global, so arming
    // it here would perturb unrelated lib tests running in parallel.
}
