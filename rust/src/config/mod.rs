//! Configuration system: device parameters (mirroring the Python build
//! side), simulation/engine config, and a TOML-subset loader.

pub mod device;
pub mod sim;
pub mod toml;

pub use device::{DeviceParams, N_COLS, N_SWEEP};
pub use sim::{FidelityTier, MaskPolicy, SensingScheme, SimConfig, VT_SEED_SALT};
