//! Simulation / engine configuration: array geometry, sensing scheme,
//! word width, coordinator knobs.  Loadable from a TOML-subset file and
//! overridable from the CLI.

use super::device::DeviceParams;
use super::toml::Doc;

/// Which sensing periphery the array uses (paper Section IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SensingScheme {
    /// Current sense amplifiers on the senseline (Section IV.A).
    Current,
    /// Voltage sensing, RBL kept precharged during hold (scheme 1).
    VoltagePrecharged,
    /// Voltage sensing, RBL discharged during hold, charged per op (scheme 2).
    VoltageDischarged,
}

impl SensingScheme {
    pub const ALL: [SensingScheme; 3] = [
        SensingScheme::Current,
        SensingScheme::VoltagePrecharged,
        SensingScheme::VoltageDischarged,
    ];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "current" => Ok(Self::Current),
            "v1" | "voltage1" | "precharged" => Ok(Self::VoltagePrecharged),
            "v2" | "voltage2" | "discharged" => Ok(Self::VoltageDischarged),
            other => Err(format!(
                "unknown sensing scheme {other:?} (expected current|v1|v2)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Current => "current",
            Self::VoltagePrecharged => "voltage-scheme1(precharged)",
            Self::VoltageDischarged => "voltage-scheme2(discharged)",
        }
    }
}

/// How faithfully dual-row activations are evaluated (the tiered
/// activation kernel).  All tiers produce identical digital decisions and
/// charge identical modeled `OpCost`s — they differ only in host
/// wall-clock cost:
///
/// * `Digital` — packed word-slice fast path over the array's shadow
///   plane (whole-row `u64` slices; `or = a | b`, `and = a & b`).  With
///   `vt_sigma == 0` it engages after a one-time margin check against
///   the analog references.  With `vt_sigma > 0` the MASKED variant
///   engages instead (see [`MaskPolicy`]): per-cell margin masks route
///   deterministic columns through the packed planes and the marginal
///   minority through the exact analog pipeline, merged by mask; if no
///   mask is available (policy `off`, collapsed margins) the engine
///   silently falls back to `Lut`.  Sampled cross-validation re-runs
///   the analog pipeline every Nth activation and counts mismatches in
///   `ArrayStats`.
/// * `Lut` — the separable `CellLut` analog pipeline (< 1e-5 relative to
///   the exact model), zero-allocation via engine scratch buffers.
/// * `Exact` — the closed-form device model
///   (`device::{senseline_current, rbl_transient}`), for validation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FidelityTier {
    Digital,
    Lut,
    Exact,
}

impl FidelityTier {
    pub const ALL: [FidelityTier; 3] =
        [FidelityTier::Digital, FidelityTier::Lut, FidelityTier::Exact];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "digital" => Ok(Self::Digital),
            "lut" => Ok(Self::Lut),
            "exact" => Ok(Self::Exact),
            other => Err(format!(
                "unknown fidelity tier {other:?} (expected digital|lut|exact)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Digital => "digital",
            Self::Lut => "lut",
            Self::Exact => "exact",
        }
    }
}

/// How the variation-aware margin masks of the masked digital tier are
/// maintained (DESIGN.md §10).  Only meaningful with `tier = digital` and
/// `vt_sigma > 0`; with `vt_sigma == 0` every cell is deterministic and
/// the policy is irrelevant.
///
/// * `Off` — no masks: under variation the digital tier fully disables
///   (the PR 4 behavior) and every activation runs the analog pipeline.
/// * `Construction` — classify each cell once at array construction with
///   the bit-independent budget (`DvtBudget::sym`); masks never change.
/// * `Write` — classify against the per-stored-bit budget; each
///   `write_bit` re-derives the cell's mask bit for the bit it now
///   stores (rewrite = invalidation + reclassification).  Never weaker
///   than `Construction`; at the paper bias the budgets coincide.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MaskPolicy {
    Off,
    Construction,
    Write,
}

impl MaskPolicy {
    pub const ALL: [MaskPolicy; 3] =
        [MaskPolicy::Off, MaskPolicy::Construction, MaskPolicy::Write];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "off" => Ok(Self::Off),
            "construction" => Ok(Self::Construction),
            "write" => Ok(Self::Write),
            other => Err(format!(
                "unknown mask policy {other:?} (expected off|construction|write)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Construction => "construction",
            Self::Write => "write",
        }
    }
}

/// Seed salt for the per-cell V_T variation stream — shared by
/// `FefetArray` (which samples the plane) and the mask-fraction
/// estimators that replay the stream without allocating it.
pub const VT_SEED_SALT: u64 = 0x5eed_d117;

/// Full engine configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub device: DeviceParams,
    /// Array rows (= number of wordlines).
    pub rows: usize,
    /// Array columns (= number of bitlines / senselines).
    pub cols: usize,
    /// Word width in bits.
    pub word_bits: usize,
    pub scheme: SensingScheme,
    /// Activation-kernel fidelity tier (see [`FidelityTier`]).  `Digital`
    /// is the default; it self-disables when `vt_sigma > 0` or the margin
    /// check fails, so results are tier-invariant by construction.
    pub tier: FidelityTier,
    /// Margin-mask maintenance policy for the masked digital tier under
    /// variation (see [`MaskPolicy`]).
    pub mask_policy: MaskPolicy,
    /// sigma of per-cell V_T variation (volts); 0 disables Monte-Carlo.
    pub vt_sigma: f64,
    /// PRNG seed for variation and workloads.
    pub seed: u64,
    /// Coordinator: worker threads (one engine each).
    pub workers: usize,
    /// Coordinator: max ops per batch.
    pub max_batch: usize,
    /// Operating frequency of CiM issue, Hz (used for leakage accounting).
    pub cim_frequency: f64,
    /// Parallelism P = N_w,CiM / N_w,TOT per activation (Fig. 5(b)).
    pub parallelism: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            device: DeviceParams::default(),
            rows: 1024,
            cols: 1024,
            word_bits: 32,
            scheme: SensingScheme::Current,
            tier: FidelityTier::Digital,
            mask_policy: MaskPolicy::Write,
            vt_sigma: 0.0,
            seed: 0xADA_2022,
            workers: 4,
            max_batch: 64,
            cim_frequency: 100e6,
            parallelism: 1.0,
        }
    }
}

impl SimConfig {
    /// Words stored per row.
    pub fn words_per_row(&self) -> usize {
        self.cols / self.word_bits
    }

    /// Total RBL capacitance per column (scales with rows).
    pub fn c_rbl(&self) -> f64 {
        self.rows as f64 * self.device.c_rbl_cell
    }

    /// Total WL capacitance per row (scales with cols).
    pub fn c_wl(&self) -> f64 {
        self.cols as f64 * self.device.c_wl_cell
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.rows == 0 || self.cols == 0 {
            return Err("array dimensions must be non-zero".into());
        }
        if self.word_bits == 0 || self.word_bits > 64 {
            return Err(format!("word_bits {} out of range 1..=64", self.word_bits));
        }
        if self.cols % self.word_bits != 0 {
            return Err(format!(
                "cols {} not a multiple of word_bits {}",
                self.cols, self.word_bits
            ));
        }
        if !(0.0..=1.0).contains(&self.parallelism) || self.parallelism <= 0.0 {
            return Err(format!("parallelism {} not in (0, 1]", self.parallelism));
        }
        if self.workers == 0 || self.max_batch == 0 {
            return Err("workers and max_batch must be >= 1".into());
        }
        Ok(())
    }

    /// Load from a TOML-subset file content; missing keys take defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = Doc::parse(text)?;
        let d = Self::default();
        let cfg = Self {
            device: DeviceParams::from_doc(&doc)?,
            rows: doc.usize_or("array.rows", d.rows)?,
            cols: doc.usize_or("array.cols", d.cols)?,
            word_bits: doc.usize_or("array.word_bits", d.word_bits)?,
            scheme: SensingScheme::parse(doc.str_or("array.scheme", "current")?)?,
            tier: FidelityTier::parse(doc.str_or("sim.tier", "digital")?)?,
            mask_policy: MaskPolicy::parse(doc.str_or("sim.mask_policy", "write")?)?,
            vt_sigma: doc.f64_or("array.vt_sigma", d.vt_sigma)?,
            seed: doc.usize_or("sim.seed", d.seed as usize)? as u64,
            workers: doc.usize_or("coordinator.workers", d.workers)?,
            max_batch: doc.usize_or("coordinator.max_batch", d.max_batch)?,
            cim_frequency: doc.f64_or("sim.cim_frequency", d.cim_frequency)?,
            parallelism: doc.f64_or("sim.parallelism", d.parallelism)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Convenience: square array of a given size with a scheme.
    pub fn square(n: usize, scheme: SensingScheme) -> Self {
        let cfg = Self {
            rows: n,
            cols: n,
            scheme,
            ..Self::default()
        };
        cfg.validate().expect("square config");
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(SensingScheme::parse("current").unwrap(), SensingScheme::Current);
        assert_eq!(
            SensingScheme::parse("v1").unwrap(),
            SensingScheme::VoltagePrecharged
        );
        assert_eq!(
            SensingScheme::parse("discharged").unwrap(),
            SensingScheme::VoltageDischarged
        );
        assert!(SensingScheme::parse("bogus").is_err());
    }

    #[test]
    fn geometry_helpers() {
        let cfg = SimConfig::square(1024, SensingScheme::Current);
        assert_eq!(cfg.words_per_row(), 32);
        assert!((cfg.c_rbl() - 204.8e-15).abs() < 1e-20);
        assert!((cfg.c_wl() - 153.6e-15).abs() < 1e-20);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = SimConfig::default();
        cfg.word_bits = 33; // cols 1024 % 33 != 0
        assert!(cfg.validate().is_err());
        cfg.word_bits = 0;
        assert!(cfg.validate().is_err());
        let mut cfg2 = SimConfig::default();
        cfg2.parallelism = 0.0;
        assert!(cfg2.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = SimConfig::from_toml(
            "[array]\nrows = 512\ncols = 512\nscheme = \"v2\"\n[device]\nvt0 = 0.7\n",
        )
        .unwrap();
        assert_eq!(cfg.rows, 512);
        assert_eq!(cfg.scheme, SensingScheme::VoltageDischarged);
        assert_eq!(cfg.device.vt0, 0.7);
        assert_eq!(cfg.word_bits, 32);
    }

    #[test]
    fn toml_bad_scheme_fails() {
        assert!(SimConfig::from_toml("[array]\nscheme = \"nope\"\n").is_err());
    }

    #[test]
    fn mask_policy_parsing_and_default() {
        assert_eq!(SimConfig::default().mask_policy, MaskPolicy::Write);
        assert_eq!(MaskPolicy::parse("off").unwrap(), MaskPolicy::Off);
        assert_eq!(
            MaskPolicy::parse("construction").unwrap(),
            MaskPolicy::Construction
        );
        assert_eq!(MaskPolicy::parse("write").unwrap(), MaskPolicy::Write);
        assert!(MaskPolicy::parse("lazy").is_err());
        let cfg = SimConfig::from_toml("[sim]\nmask_policy = \"off\"\n").unwrap();
        assert_eq!(cfg.mask_policy, MaskPolicy::Off);
        assert!(SimConfig::from_toml("[sim]\nmask_policy = \"nope\"\n").is_err());
    }

    #[test]
    fn tier_parsing_and_default() {
        assert_eq!(SimConfig::default().tier, FidelityTier::Digital);
        assert_eq!(FidelityTier::parse("lut").unwrap(), FidelityTier::Lut);
        assert_eq!(FidelityTier::parse("exact").unwrap(), FidelityTier::Exact);
        assert!(FidelityTier::parse("analog").is_err());
        let cfg = SimConfig::from_toml("[sim]\ntier = \"exact\"\n").unwrap();
        assert_eq!(cfg.tier, FidelityTier::Exact);
        assert!(SimConfig::from_toml("[sim]\ntier = \"nope\"\n").is_err());
    }
}
