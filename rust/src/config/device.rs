//! Device parameters — the exact mirror of `python/compile/params.py`.
//!
//! KEEP IN SYNC: these constants are the single source of truth on the
//! Rust side; the cross-validation integration test executes the AOT
//! artifacts and checks the Rust behavioral model against the JAX/Pallas
//! numerics, which is what pins the two copies together.

/// FeFET + array electrical parameters (paper Fig. 2(b) + Section IV).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceParams {
    // ---- 45 nm FET (alpha-power law + smooth subthreshold) ----
    pub vdd: f64,
    pub phi_t: f64,
    pub n_ss: f64,
    pub alpha_sat: f64,
    pub k_fet: f64,
    pub v_dsat: f64,

    // ---- HZO ferroelectric layer (Miller / Preisach-lite) ----
    pub t_fe: f64,
    pub ps: f64,
    pub pr: f64,
    pub ec: f64,
    pub eps_fe: f64,
    pub tau_fe: f64,
    pub kappa_fe: f64,

    // ---- FeFET threshold map ----
    pub vt0: f64,
    pub dvt_mw: f64,
    pub p_store: f64,

    // ---- Section IV bias conditions ----
    pub v_read: f64,
    pub v_gread1: f64,
    pub v_gread2: f64,
    pub v_set: f64,
    pub v_reset: f64,

    // ---- Array electricals (per cell) ----
    pub c_rbl_cell: f64,
    pub c_wl_cell: f64,
    pub t_step: f64,
    pub n_steps: usize,
}

impl Default for DeviceParams {
    fn default() -> Self {
        Self {
            vdd: 1.0,
            phi_t: 0.0259,
            n_ss: 1.5,
            alpha_sat: 1.3,
            k_fet: 6.0e-5,
            v_dsat: 0.3,

            t_fe: 8e-9,
            ps: 0.25,
            pr: 0.20,
            ec: 1.2e8,
            eps_fe: 30.0,
            tau_fe: 5e-9,
            kappa_fe: 0.5,

            vt0: 0.65,
            dvt_mw: 0.8,
            p_store: 0.8,

            v_read: 1.0,
            v_gread1: 0.83,
            v_gread2: 1.0,
            v_set: 3.7,
            v_reset: -5.0,

            c_rbl_cell: 0.2e-15,
            c_wl_cell: 0.15e-15,
            t_step: 0.02e-9,
            n_steps: 128,
        }
    }
}

impl DeviceParams {
    /// Miller domain-spread parameter, eq. (2): Ec / ln((Ps+Pr)/(Ps-Pr)).
    pub fn sigma_e(&self) -> f64 {
        self.ec / ((self.ps + self.pr) / (self.ps - self.pr)).ln()
    }

    /// Stored polarization for a logic bit (+-p_store * Ps).
    pub fn pol_of_bit(&self, bit: bool) -> f64 {
        if bit {
            self.p_store * self.ps
        } else {
            -self.p_store * self.ps
        }
    }

    /// Gate-referred coercive voltage: the WL voltage whose divided-down
    /// FE field equals Ec.  The read-disturb design rule is
    /// `v_gread2 < v_c_gate`.
    pub fn v_c_gate(&self) -> f64 {
        self.ec * self.t_fe / self.kappa_fe
    }

    /// Overlay values from a parsed config document (section `[device]`).
    pub fn from_doc(doc: &super::toml::Doc) -> Result<Self, String> {
        let d = Self::default();
        Ok(Self {
            vdd: doc.f64_or("device.vdd", d.vdd)?,
            phi_t: doc.f64_or("device.phi_t", d.phi_t)?,
            n_ss: doc.f64_or("device.n_ss", d.n_ss)?,
            alpha_sat: doc.f64_or("device.alpha_sat", d.alpha_sat)?,
            k_fet: doc.f64_or("device.k_fet", d.k_fet)?,
            v_dsat: doc.f64_or("device.v_dsat", d.v_dsat)?,
            t_fe: doc.f64_or("device.t_fe", d.t_fe)?,
            ps: doc.f64_or("device.ps", d.ps)?,
            pr: doc.f64_or("device.pr", d.pr)?,
            ec: doc.f64_or("device.ec", d.ec)?,
            eps_fe: doc.f64_or("device.eps_fe", d.eps_fe)?,
            tau_fe: doc.f64_or("device.tau_fe", d.tau_fe)?,
            kappa_fe: doc.f64_or("device.kappa_fe", d.kappa_fe)?,
            vt0: doc.f64_or("device.vt0", d.vt0)?,
            dvt_mw: doc.f64_or("device.dvt_mw", d.dvt_mw)?,
            p_store: doc.f64_or("device.p_store", d.p_store)?,
            v_read: doc.f64_or("device.v_read", d.v_read)?,
            v_gread1: doc.f64_or("device.v_gread1", d.v_gread1)?,
            v_gread2: doc.f64_or("device.v_gread2", d.v_gread2)?,
            v_set: doc.f64_or("device.v_set", d.v_set)?,
            v_reset: doc.f64_or("device.v_reset", d.v_reset)?,
            c_rbl_cell: doc.f64_or("device.c_rbl_cell", d.c_rbl_cell)?,
            c_wl_cell: doc.f64_or("device.c_wl_cell", d.c_wl_cell)?,
            t_step: doc.f64_or("device.t_step", d.t_step)?,
            n_steps: doc.usize_or("device.n_steps", d.n_steps)?,
        })
    }
}

/// Static column width of the AOT artifacts (mirror of params.N_COLS).
pub const N_COLS: usize = 1024;
/// Static sweep length of the AOT artifacts (mirror of params.N_SWEEP).
pub const N_SWEEP: usize = 512;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_biases() {
        let p = DeviceParams::default();
        assert_eq!(p.v_read, 1.0);
        assert_eq!(p.v_gread1, 0.83);
        assert_eq!(p.v_gread2, 1.0);
        assert_eq!(p.v_set, 3.7);
        assert_eq!(p.v_reset, -5.0);
    }

    #[test]
    fn asymmetry_is_present() {
        let p = DeviceParams::default();
        assert!(p.v_gread1 < p.v_gread2, "ADRA requires V_GREAD1 < V_GREAD2");
    }

    #[test]
    fn read_disturb_design_rule() {
        let p = DeviceParams::default();
        assert!(
            p.v_gread2 < p.v_c_gate(),
            "V_GREAD ({}) must be below gate-referred V_C ({})",
            p.v_gread2,
            p.v_c_gate()
        );
        assert!(p.v_set > p.v_c_gate(), "V_SET must switch polarization");
    }

    #[test]
    fn sigma_matches_eq2() {
        let p = DeviceParams::default();
        let expect = 1.2e8 / (0.45f64 / 0.05).ln();
        assert!((p.sigma_e() - expect).abs() < 1.0);
    }

    #[test]
    fn pol_of_bit_signs() {
        let p = DeviceParams::default();
        assert!(p.pol_of_bit(true) > 0.0);
        assert!(p.pol_of_bit(false) < 0.0);
        assert_eq!(p.pol_of_bit(true), -p.pol_of_bit(false));
    }

    #[test]
    fn config_overlay() {
        let doc = super::super::toml::Doc::parse("[device]\nvt0 = 0.7\n").unwrap();
        let p = DeviceParams::from_doc(&doc).unwrap();
        assert_eq!(p.vt0, 0.7);
        assert_eq!(p.v_read, 1.0); // untouched default
    }
}
