//! TOML-subset parser for configuration files (no `serde`/`toml` crates).
//!
//! Supports the subset the config system uses: `[section]` headers,
//! `key = value` with string / integer / float / boolean values, comments
//! (`#`), and blank lines.  Unknown syntax is a hard error — configs should
//! fail loudly, not half-parse.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key -> value`; keys before any section header
/// live in the "" (root) section.
#[derive(Debug, Default)]
pub struct Doc {
    values: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            if doc.values.insert(full_key.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key {full_key}", lineno + 1));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| format!("{key}: expected number, got {v:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_usize()
                .ok_or_else(|| format!("{key}: expected non-negative int, got {v:?}")),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str, String> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_str()
                .ok_or_else(|| format!("{key}: expected string, got {v:?}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
# top comment
root_key = 1
[device]
vt0 = 0.65          # volts
name = "hzo"
enabled = true
count = 42
"#,
        )
        .unwrap();
        assert_eq!(doc.get("root_key"), Some(&Value::Int(1)));
        assert_eq!(doc.get("device.vt0"), Some(&Value::Float(0.65)));
        assert_eq!(doc.get("device.name"), Some(&Value::Str("hzo".into())));
        assert_eq!(doc.get("device.enabled"), Some(&Value::Bool(true)));
        assert_eq!(doc.f64_or("device.count", 0.0).unwrap(), 42.0);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.f64_or("nope", 3.5).unwrap(), 3.5);
        assert_eq!(doc.usize_or("nope", 7).unwrap(), 7);
        assert_eq!(doc.str_or("nope", "d").unwrap(), "d");
    }

    #[test]
    fn duplicate_key_is_error() {
        assert!(Doc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_syntax_is_error() {
        assert!(Doc::parse("just a line").is_err());
        assert!(Doc::parse("[unterminated").is_err());
        assert!(Doc::parse("k = ").is_err());
        assert!(Doc::parse("k = \"open").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("k"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("a = -5\nb = 1.2e8\nc = -5.0").unwrap();
        assert_eq!(doc.get("a"), Some(&Value::Int(-5)));
        assert_eq!(doc.f64_or("b", 0.0).unwrap(), 1.2e8);
        assert_eq!(doc.f64_or("c", 0.0).unwrap(), -5.0);
    }

    #[test]
    fn type_mismatch_errors() {
        let doc = Doc::parse("k = \"str\"").unwrap();
        assert!(doc.f64_or("k", 0.0).is_err());
        assert!(doc.usize_or("k", 0).is_err());
    }
}
