//! Fig. 5: voltage-sensing scheme 1 vs scheme 2 — (a) energy per CiM op
//! vs operation frequency (leakage trade-off) and (b) vs parallelism P
//! (half-select trade-off), with the crossover points.

use crate::config::{SensingScheme, SimConfig};
use crate::energy::EnergyModel;
use crate::util::table::{fmt_si, Table};

/// One frequency point: (freq, E_scheme1, E_scheme2) per word op.
pub fn fig5a_sweep(size: usize) -> Vec<(f64, f64, f64)> {
    let m = EnergyModel::new(&SimConfig::square(size, SensingScheme::VoltagePrecharged));
    let freqs = [0.5e6, 1e6, 2e6, 4e6, 7.53e6, 16e6, 32e6, 64e6, 128e6];
    freqs
        .iter()
        .map(|&f| {
            (
                f,
                m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, f),
                m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, f),
            )
        })
        .collect()
}

/// One parallelism point: (P, E_scheme1, E_scheme2) per row activation.
pub fn fig5b_sweep(size: usize) -> Vec<(f64, f64, f64)> {
    let m = EnergyModel::new(&SimConfig::square(size, SensingScheme::VoltagePrecharged));
    (1..=16)
        .map(|i| {
            let p = i as f64 / 16.0;
            (
                p,
                m.row_activation_energy(SensingScheme::VoltagePrecharged, p),
                m.row_activation_energy(SensingScheme::VoltageDischarged, p),
            )
        })
        .collect()
}

/// Find the scheme1/scheme2 crossover frequency by bisection.
pub fn crossover_frequency(size: usize) -> f64 {
    let m = EnergyModel::new(&SimConfig::square(size, SensingScheme::VoltagePrecharged));
    let (mut lo, mut hi): (f64, f64) = (1e5, 1e9);
    for _ in 0..60 {
        let mid = (lo * hi).sqrt();
        let e1 = m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, mid);
        let e2 = m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, mid);
        if e1 > e2 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (lo * hi).sqrt()
}

/// Find the parallelism crossover by bisection.
pub fn crossover_parallelism(size: usize) -> f64 {
    let m = EnergyModel::new(&SimConfig::square(size, SensingScheme::VoltagePrecharged));
    let (mut lo, mut hi): (f64, f64) = (1.0 / 64.0, 1.0);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let e1 = m.row_activation_energy(SensingScheme::VoltagePrecharged, mid);
        let e2 = m.row_activation_energy(SensingScheme::VoltageDischarged, mid);
        if e1 > e2 {
            lo = mid; // scheme 1 still worse (half-select dominated)
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

pub fn print_fig5() {
    let mut t = Table::new(&["CiM frequency", "scheme 1 (precharged)", "scheme 2 (discharged)"])
        .with_title("Fig 5(a): energy per CiM op vs frequency, 1024x1024");
    for (f, e1, e2) in fig5a_sweep(1024) {
        t.row(&[fmt_si(f, "Hz"), fmt_si(e1, "J"), fmt_si(e2, "J")]);
    }
    t.print();
    println!(
        "crossover: {} (paper: 7.53 MHz)\n",
        fmt_si(crossover_frequency(1024), "Hz")
    );

    let mut t2 = Table::new(&["parallelism P", "scheme 1", "scheme 2"])
        .with_title("Fig 5(b): energy per row activation vs parallelism, 1024x1024");
    for (p, e1, e2) in fig5b_sweep(1024) {
        t2.row(&[format!("{:.3}", p), fmt_si(e1, "J"), fmt_si(e2, "J")]);
    }
    t2.print();
    println!(
        "crossover: P = {:.3} (paper: ~0.42)\n",
        crossover_parallelism(1024)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme2_flat_scheme1_falls_with_frequency() {
        let sweep = fig5a_sweep(1024);
        for w in sweep.windows(2) {
            let (_, e1a, e2a) = w[0];
            let (_, e1b, e2b) = w[1];
            assert!(e1b < e1a, "scheme1 per-op energy falls with frequency");
            assert!((e2a - e2b).abs() < 1e-20, "scheme2 frequency-independent");
        }
    }

    #[test]
    fn crossovers_match_paper() {
        let f = crossover_frequency(1024);
        assert!((f - 7.53e6).abs() / 7.53e6 < 0.05, "freq crossover {f}");
        let p = crossover_parallelism(1024);
        assert!((p - 0.42).abs() < 0.04, "parallelism crossover {p}");
    }

    #[test]
    fn scheme2_wins_at_low_parallelism() {
        let sweep = fig5b_sweep(1024);
        let (p_lo, e1_lo, e2_lo) = sweep[0];
        assert!(p_lo < 0.1);
        assert!(e2_lo < e1_lo, "scheme 2 must win at low P");
        let (_, e1_hi, e2_hi) = sweep.last().copied().unwrap();
        assert!(e1_hi < e2_hi, "scheme 1 must win at P = 1");
    }
}
