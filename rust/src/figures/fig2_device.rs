//! Fig. 2(b)/(c): the FeFET simulation parameters and the calibrated
//! I_D-V_G hysteresis curve.  The curve comes from the behavioral model;
//! `adra validate` additionally regenerates it through the `iv_sweep`
//! AOT artifact over PJRT and cross-checks the two.

use crate::config::DeviceParams;
use crate::device;
use crate::util::table::{fmt_si, Table};

/// One point of the I-V sweep.
#[derive(Clone, Copy, Debug)]
pub struct IvPoint {
    pub v_g: f64,
    pub i_d: f64,
    pub pol: f64,
}

/// Triangular +-5 V sweep of `n` points; returns up + down branches.
pub fn fig2_iv_curve(p: &DeviceParams, n: usize) -> Vec<IvPoint> {
    let vg_at = |i: usize| -> f64 {
        let half = n / 2;
        if i < half {
            -5.0 + 10.0 * i as f64 / (half - 1) as f64
        } else {
            5.0 - 10.0 * (i - half) as f64 / (n - half - 1) as f64
        }
    };
    let dwell = p.t_step * 50.0;
    let mut pol = -p.p_store * p.ps;
    (0..n)
        .map(|i| {
            let v_g = vg_at(i);
            pol = device::miller::step(p, pol, v_g, dwell);
            let i_d = device::cell_current(p, v_g, 0.05, pol, 0.0);
            IvPoint { v_g, i_d, pol }
        })
        .collect()
}

pub fn print_fig2(p: &DeviceParams) {
    let mut t = Table::new(&["parameter", "value"])
        .with_title("Fig 2(b): FeFET simulation parameters");
    let rows: Vec<(&str, String)> = vec![
        ("T_FE", fmt_si(p.t_fe, "m")),
        ("P_S", format!("{:.0} uC/cm^2", p.ps * 100.0)),
        ("P_R", format!("{:.0} uC/cm^2", p.pr * 100.0)),
        ("E_C", format!("{:.1} MV/cm", p.ec / 1e8)),
        ("eps_FE", format!("{:.0}", p.eps_fe)),
        ("tau_FE", fmt_si(p.tau_fe, "s")),
        ("VT0 (mid)", format!("{:.2} V", p.vt0)),
        ("memory window", format!("{:.2} V", p.dvt_mw)),
        ("V_READ", format!("{:.2} V", p.v_read)),
        ("V_GREAD1", format!("{:.2} V", p.v_gread1)),
        ("V_GREAD2", format!("{:.2} V", p.v_gread2)),
        ("V_SET", format!("{:.2} V", p.v_set)),
        ("V_RESET", format!("{:.2} V", p.v_reset)),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t.print();

    let curve = fig2_iv_curve(p, 64);
    let mut t2 = Table::new(&["V_G", "I_D (up/down)", "P"])
        .with_title("Fig 2(c): I_D-V_G hysteresis (16-point summary)");
    for pt in curve.iter().step_by(4) {
        t2.row(&[
            format!("{:+.2} V", pt.v_g),
            fmt_si(pt.i_d, "A"),
            format!("{:+.3} C/m^2", pt.pol),
        ]);
    }
    t2.print();
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hysteresis_branches_differ_at_zero_crossing() {
        let p = DeviceParams::default();
        let curve = fig2_iv_curve(&p, 256);
        // find the up-branch and down-branch polarization near V_G = 0.5
        let up = curve[..128].iter().min_by(|a, b| {
            (a.v_g - 0.5).abs().partial_cmp(&(b.v_g - 0.5).abs()).unwrap()
        });
        let dn = curve[128..].iter().min_by(|a, b| {
            (a.v_g - 0.5).abs().partial_cmp(&(b.v_g - 0.5).abs()).unwrap()
        });
        let (up, dn) = (up.unwrap(), dn.unwrap());
        assert!(
            (dn.pol - up.pol) > 0.2 * p.pr,
            "no loop: up {} dn {}",
            up.pol,
            dn.pol
        );
        // the current window follows the polarization window
        assert!(dn.i_d > up.i_d);
    }

    #[test]
    fn currents_nonnegative_and_bounded() {
        let p = DeviceParams::default();
        for pt in fig2_iv_curve(&p, 128) {
            assert!(pt.i_d >= 0.0);
            assert!(pt.i_d < 1e-3);
            assert!(pt.pol.abs() <= p.ps + 1e-12);
        }
    }
}
