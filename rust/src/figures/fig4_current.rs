//! Fig. 4: current-based sensing — (a) the per-word energy decomposition
//! at 1024x1024, (b) energy decrease and (c) speedup vs array size,
//! ADRA CiM against the two-read near-memory baseline.

use crate::config::{SensingScheme, SimConfig};
use crate::energy::{EnergyModel, Improvement};
use crate::util::table::{fmt_pct, fmt_si, Table};

use super::ARRAY_SIZES;

/// One array-size point of the Fig. 4(b)/(c) sweep.
#[derive(Clone, Copy, Debug)]
pub struct Fig4Row {
    pub size: usize,
    pub improvement: Improvement,
    pub cim_over_read: f64,
}

/// Sweep for the configured scheme (Fig. 4 uses Current; Figs. 6/7 reuse
/// this shape through `fig67_voltage`).
pub fn fig4_sweep(scheme: SensingScheme) -> Vec<Fig4Row> {
    ARRAY_SIZES
        .iter()
        .map(|&size| {
            let m = EnergyModel::new(&SimConfig::square(size, scheme));
            Fig4Row {
                size,
                improvement: Improvement::of(&m.cim_cost(), &m.baseline_cost()),
                cim_over_read: m.cim_cost().energy.total() / m.read_cost().energy.total(),
            }
        })
        .collect()
}

pub(crate) fn print_components(scheme: SensingScheme, title: &str) {
    let m = EnergyModel::new(&SimConfig::square(1024, scheme));
    let read = m.read_cost();
    let cim = m.cim_cost();
    let base = m.baseline_cost();
    let mut t = Table::new(&["component", "read", "ADRA CiM", "baseline (2R+NM)"])
        .with_title(title.to_string());
    let rows: Vec<(&str, f64, f64, f64)> = vec![
        ("RBL charge", read.energy.rbl, cim.energy.rbl, base.energy.rbl),
        ("WL charge", read.energy.wl, cim.energy.wl, base.energy.wl),
        ("current flow+sense", read.energy.flow, cim.energy.flow, base.energy.flow),
        ("peripheral", read.energy.peripheral, cim.energy.peripheral, base.energy.peripheral),
        (
            "TOTAL",
            read.energy.total(),
            cim.energy.total(),
            base.energy.total(),
        ),
    ];
    for (k, r, c, b) in rows {
        t.row(&[k.to_string(), fmt_si(r, "J"), fmt_si(c, "J"), fmt_si(b, "J")]);
    }
    t.print();
    println!(
        "read RBL share {} | CiM RBL share {} | CiM/read = {:.3}x\n",
        fmt_pct(read.energy.rbl_fraction()),
        fmt_pct(cim.energy.rbl_fraction()),
        cim.energy.total() / read.energy.total()
    );
}

pub(crate) fn print_sweep(scheme: SensingScheme, title: &str) {
    let mut t = Table::new(&["array size", "energy decrease", "speedup", "EDP decrease"])
        .with_title(title.to_string());
    for row in fig4_sweep(scheme) {
        t.row(&[
            format!("{0}x{0}", row.size),
            fmt_pct(row.improvement.energy_decrease),
            format!("{:.3}x", row.improvement.speedup),
            fmt_pct(row.improvement.edp_decrease),
        ]);
    }
    t.print();
    println!();
}

pub fn print_fig4() {
    print_components(
        SensingScheme::Current,
        "Fig 4(a): energy components per 32-bit word, 1024x1024, current sensing",
    );
    print_sweep(
        SensingScheme::Current,
        "Fig 4(b)/(c): ADRA vs near-memory baseline, current sensing",
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_matches_paper_at_1024() {
        let rows = fig4_sweep(SensingScheme::Current);
        let last = rows.last().unwrap();
        assert_eq!(last.size, 1024);
        assert!((last.improvement.energy_decrease - 0.4118).abs() < 0.005);
        assert!((last.improvement.speedup - 1.94).abs() < 0.02);
        assert!((last.cim_over_read - 1.24).abs() < 0.01);
    }

    #[test]
    fn benefits_monotone_in_size() {
        let rows = fig4_sweep(SensingScheme::Current);
        for w in rows.windows(2) {
            assert!(w[1].improvement.energy_decrease > w[0].improvement.energy_decrease);
            assert!(w[1].improvement.speedup > w[0].improvement.speedup);
            assert!(w[1].improvement.edp_decrease > w[0].improvement.edp_decrease);
        }
    }
}
