//! Fig. 3(b)/(c): ADRA's asymmetric activation — the four distinct I_SL
//! levels, the three sense-amplifier references, and the sense margins.

use crate::config::DeviceParams;
use crate::device;
use crate::sensing::{CurrentRefs, MarginReport};
use crate::util::table::{fmt_si, Table};

pub struct Fig3Data {
    pub rows: Vec<(&'static str, f64)>,
    pub refs: CurrentRefs,
    pub margins: MarginReport,
}

pub fn fig3_table(p: &DeviceParams) -> Fig3Data {
    let l = device::isl_levels(p, p.v_gread1, p.v_gread2);
    Fig3Data {
        rows: vec![
            ("(A,B)=(0,0)", l[0b00]),
            ("(A,B)=(1,0)", l[0b10]),
            ("(A,B)=(0,1)", l[0b01]),
            ("(A,B)=(1,1)", l[0b11]),
        ],
        refs: CurrentRefs::derive(p, p.v_gread1, p.v_gread2),
        margins: MarginReport::evaluate(p, p.v_gread1, p.v_gread2, 1024.0 * p.c_rbl_cell),
    }
}

pub fn print_fig3(p: &DeviceParams) {
    let d = fig3_table(p);
    let mut t = Table::new(&["input vector", "I_SL"]).with_title(format!(
        "Fig 3(c): ADRA asymmetric activation (V_GREAD1={} V, V_GREAD2={} V)",
        p.v_gread1, p.v_gread2
    ));
    for (label, isl) in &d.rows {
        t.row(&[label.to_string(), fmt_si(*isl, "A")]);
    }
    t.print();
    println!(
        "Fig 3(b) references: I_REF-OR = {}, I_REF-B = {}, I_REF-AND = {}",
        fmt_si(d.refs.i_ref_or, "A"),
        fmt_si(d.refs.i_ref_b, "A"),
        fmt_si(d.refs.i_ref_and, "A")
    );
    println!(
        "one-to-one mapping: {} | current margin {} (>1 uA: {}) | voltage \
         margin {} (>50 mV: {})\n",
        d.margins.one_to_one,
        fmt_si(d.margins.current_margin, "A"),
        d.margins.current_margin > 1e-6,
        fmt_si(d.margins.voltage_margin, "V"),
        d.margins.voltage_margin > 0.050
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_levels_ascending_with_references_between() {
        let d = fig3_table(&DeviceParams::default());
        let vals: Vec<f64> = d.rows.iter().map(|r| r.1).collect();
        // table rows are printed in ascending I_SL order: 00, 10, 01, 11
        for w in vals.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(vals[0] < d.refs.i_ref_or && d.refs.i_ref_or < vals[1]);
        assert!(vals[1] < d.refs.i_ref_b && d.refs.i_ref_b < vals[2]);
        assert!(vals[2] < d.refs.i_ref_and && d.refs.i_ref_and < vals[3]);
        assert!(d.margins.meets_paper_targets());
    }
}
