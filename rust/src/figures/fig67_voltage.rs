//! Figs. 6 & 7: the voltage-sensing evaluations — same layout as Fig. 4
//! (components at 1024 + improvement sweep), for scheme 1 (precharged)
//! and scheme 2 (discharged).

use crate::config::SensingScheme;

use super::fig4_current::{fig4_sweep, print_components, print_sweep, Fig4Row};

pub fn fig67_sweep(scheme: SensingScheme) -> Vec<Fig4Row> {
    fig4_sweep(scheme)
}

pub fn print_fig6() {
    print_components(
        SensingScheme::VoltagePrecharged,
        "Fig 6(a): energy components per word, 1024x1024, voltage scheme 1 (precharged)",
    );
    print_sweep(
        SensingScheme::VoltagePrecharged,
        "Fig 6(b)/(c): ADRA vs baseline, voltage scheme 1",
    );
}

pub fn print_fig7() {
    print_components(
        SensingScheme::VoltageDischarged,
        "Fig 7(a): energy components per word, 1024x1024, voltage scheme 2 (discharged)",
    );
    print_sweep(
        SensingScheme::VoltageDischarged,
        "Fig 7(b)/(c): ADRA vs baseline, voltage scheme 2",
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_scheme1_bands() {
        let rows = fig67_sweep(SensingScheme::VoltagePrecharged);
        // paper range quoted over the sweep's 256..1024 portion
        let in_range: Vec<_> = rows.iter().filter(|r| r.size >= 256).collect();
        let first = in_range.first().unwrap();
        let last = in_range.last().unwrap();
        assert!((first.improvement.speedup - 1.57).abs() < 0.03, "{first:?}");
        assert!((last.improvement.speedup - 1.73).abs() < 0.03, "{last:?}");
        for r in &in_range {
            let overhead = -r.improvement.energy_decrease;
            assert!(
                (0.17..0.26).contains(&overhead),
                "scheme1 energy overhead out of band at {}: {overhead}",
                r.size
            );
        }
        assert!((first.improvement.edp_decrease - 0.2326).abs() < 0.02);
        assert!((last.improvement.edp_decrease - 0.2881).abs() < 0.02);
    }

    #[test]
    fn fig7_scheme2_bands() {
        let rows = fig67_sweep(SensingScheme::VoltageDischarged);
        let in_range: Vec<_> = rows.iter().filter(|r| r.size >= 256).collect();
        let first = in_range.first().unwrap();
        let last = in_range.last().unwrap();
        assert!((first.improvement.energy_decrease - 0.355).abs() < 0.02);
        assert!((last.improvement.energy_decrease - 0.458).abs() < 0.02);
        assert!((first.improvement.speedup - 1.945).abs() < 0.02);
        assert!((last.improvement.speedup - 1.983).abs() < 0.02);
        assert!((first.improvement.edp_decrease - 0.6683).abs() < 0.02);
        assert!((last.improvement.edp_decrease - 0.726).abs() < 0.02);
    }

    #[test]
    fn headline_claim_23_to_72_pct_edp() {
        // the abstract's 23.2% - 72.6% EDP decrease across all schemes
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for scheme in SensingScheme::ALL {
            for r in fig67_sweep(scheme) {
                if r.size >= 256 {
                    lo = lo.min(r.improvement.edp_decrease);
                    hi = hi.max(r.improvement.edp_decrease);
                }
            }
        }
        assert!((lo - 0.232).abs() < 0.02, "low end {lo}");
        assert!((hi - 0.726).abs() < 0.02, "high end {hi}");
    }
}
