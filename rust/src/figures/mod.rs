//! Figure harnesses: one function per paper figure/table regenerating the
//! same rows/series.  Each harness returns structured data (asserted on by
//! tests and benches) and has a `print_*` companion used by the
//! `adra figures` CLI command.

pub mod fig1_baseline_mapping;
pub mod fig2_device;
pub mod fig3_adra_mapping;
pub mod fig4_current;
pub mod fig5_tradeoffs;
pub mod fig67_voltage;

pub use fig1_baseline_mapping::{fig1_table, print_fig1};
pub use fig2_device::{fig2_iv_curve, print_fig2};
pub use fig3_adra_mapping::{fig3_table, print_fig3};
pub use fig4_current::{fig4_sweep, print_fig4, Fig4Row};
pub use fig5_tradeoffs::{fig5a_sweep, fig5b_sweep, print_fig5};
pub use fig67_voltage::{fig67_sweep, print_fig6, print_fig7};

/// Array sizes swept in Figs. 4, 6, 7 ("as a function of the array size").
pub const ARRAY_SIZES: [usize; 4] = [128, 256, 512, 1024];
