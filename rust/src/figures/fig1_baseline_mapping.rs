//! Fig. 1(b)/(c): the prior-work *symmetric* activation — reference
//! currents and the I_SL table showing the many-to-one mapping
//! ((0,1) and (1,0) indistinguishable).

use crate::config::DeviceParams;
use crate::device;
use crate::sensing::CurrentRefs;
use crate::util::table::{fmt_si, Table};

/// (label, I_SL) rows of Fig. 1(c) plus the two references of Fig. 1(b).
pub struct Fig1Data {
    pub rows: Vec<(&'static str, f64)>,
    pub i_ref_or: f64,
    pub i_ref_and: f64,
    /// |I_SL(0,1) - I_SL(1,0)| — zero is the mapping problem.
    pub ambiguity_gap: f64,
}

pub fn fig1_table(p: &DeviceParams) -> Fig1Data {
    let vg = p.v_gread2; // both wordlines at the same V_GREAD
    let l = device::isl_levels(p, vg, vg);
    let refs = CurrentRefs::derive(p, vg, vg);
    Fig1Data {
        rows: vec![
            ("(A,B)=(0,0)", l[0b00]),
            ("(A,B)=(0,1)", l[0b01]),
            ("(A,B)=(1,0)", l[0b10]),
            ("(A,B)=(1,1)", l[0b11]),
        ],
        i_ref_or: refs.i_ref_or,
        i_ref_and: refs.i_ref_and,
        ambiguity_gap: (l[0b01] - l[0b10]).abs(),
    }
}

pub fn print_fig1(p: &DeviceParams) {
    let d = fig1_table(p);
    let mut t = Table::new(&["input vector", "I_SL"])
        .with_title("Fig 1(c): symmetric dual-row activation (prior work)");
    for (label, isl) in &d.rows {
        t.row(&[label.to_string(), fmt_si(*isl, "A")]);
    }
    t.print();
    println!("Fig 1(b) references: I_REF-OR = {}, I_REF-AND = {}",
             fmt_si(d.i_ref_or, "A"), fmt_si(d.i_ref_and, "A"));
    println!(
        "many-to-one mapping: |I(0,1) - I(1,0)| = {} -> single-cycle \
         subtraction impossible\n",
        fmt_si(d.ambiguity_gap, "A")
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_mapping_is_ambiguous() {
        let d = fig1_table(&DeviceParams::default());
        let i01 = d.rows[1].1;
        let i10 = d.rows[2].1;
        assert!(d.ambiguity_gap / i01.max(i10) < 1e-9);
        // but three levels still separate OR and AND
        assert!(d.rows[0].1 < d.i_ref_or && d.i_ref_or < i01);
        assert!(i01 < d.i_ref_and && d.i_ref_and < d.rows[3].1);
    }
}
