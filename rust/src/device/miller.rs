//! Monotone-branch Miller polarization dynamics (paper eqs. (1)-(2)).
//!
//! Mirrors `python/compile/kernels/ref.py::miller_step`.  The branch
//! rectification — ascending drive can only raise P, descending only lower
//! it, nothing moves at E = 0 — gives retention and the Fig. 2(c)
//! hysteresis loop without tracking dE/dt history.

use crate::config::DeviceParams;

/// Branch saturation targets P+-(E), eq. (1): (ascending, descending).
#[inline]
pub fn branch_targets(p: &DeviceParams, e_fe: f64) -> (f64, f64) {
    let s2 = 2.0 * p.sigma_e();
    let up = p.ps * ((e_fe - p.ec) / s2).tanh();
    let dn = p.ps * ((e_fe + p.ec) / s2).tanh();
    (up, dn)
}

/// One explicit-Euler step of the lagged dynamics:
/// dP/dt = rectified (P_branch(E) - P) / tau.
#[inline]
pub fn step(p: &DeviceParams, pol: f64, v_g: f64, dt: f64) -> f64 {
    let e_fe = p.kappa_fe * v_g / p.t_fe;
    let (up, dn) = branch_targets(p, e_fe);
    let drive_up = if e_fe > 0.0 { (up - pol).max(0.0) } else { 0.0 };
    let drive_dn = if e_fe < 0.0 { (dn - pol).min(0.0) } else { 0.0 };
    let next = pol + (drive_up + drive_dn) * (dt / p.tau_fe);
    next.clamp(-p.ps, p.ps)
}

/// Relax polarization under a constant gate bias for `steps` x `dt`.
pub fn relax(p: &DeviceParams, mut pol: f64, v_g: f64, dt: f64, steps: usize) -> f64 {
    for _ in 0..steps {
        pol = step(p, pol, v_g, dt);
    }
    pol
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn retention_at_zero_bias() {
        let p = p();
        let pol = relax(&p, 0.13, 0.0, 1e-6, 1000);
        assert_eq!(pol, 0.13);
    }

    #[test]
    fn set_pulse_switches_up() {
        let p = p();
        let pol = relax(&p, -p.p_store * p.ps, p.v_set, 1e-9, 500);
        assert!(pol > 0.5 * p.pr, "pol={pol}");
    }

    #[test]
    fn reset_pulse_switches_down() {
        let p = p();
        let pol = relax(&p, p.p_store * p.ps, p.v_reset, 1e-9, 500);
        assert!(pol < -0.5 * p.pr, "pol={pol}");
    }

    #[test]
    fn read_bias_never_flips_lrs() {
        let p = p();
        let pol = relax(&p, p.p_store * p.ps, p.v_gread2, 1e-9, 5000);
        assert!(pol > 0.5 * p.ps, "read disturb flipped LRS: pol={pol}");
    }

    #[test]
    fn polarization_bounded() {
        let p = p();
        let mut pol = 0.0;
        for &vg in &[8.0, -8.0, 8.0, -8.0] {
            pol = relax(&p, pol, vg, 1e-8, 200);
            assert!(pol.abs() <= p.ps + 1e-12);
        }
    }

    #[test]
    fn hysteresis_loop_area_positive() {
        let p = p();
        let n = 200;
        let mut pol = -p.p_store * p.ps;
        let sweep: Vec<f64> = (0..n)
            .map(|i| -5.0 + 10.0 * i as f64 / (n - 1) as f64)
            .collect();
        let mut up_curve = Vec::new();
        for &vg in &sweep {
            pol = step(&p, pol, vg, 1e-9);
            up_curve.push(pol);
        }
        let mut dn_curve = Vec::new();
        for &vg in sweep.iter().rev() {
            pol = step(&p, pol, vg, 1e-9);
            dn_curve.push(pol);
        }
        dn_curve.reverse();
        let area: f64 = up_curve
            .iter()
            .zip(&dn_curve)
            .map(|(u, d)| u - d)
            .sum::<f64>()
            .abs()
            * 10.0
            / n as f64;
        assert!(area > 0.001 * p.ps, "no hysteresis: area={area}");
    }

    #[test]
    fn branch_ordering() {
        // descending branch target >= ascending at any field
        let p = p();
        for i in -50..=50 {
            let e = i as f64 * 1e7;
            let (up, dn) = branch_targets(&p, e);
            assert!(dn >= up, "branches crossed at E={e}");
        }
    }
}
