//! Behavioral FeFET device model — the Rust mirror of the JAX/Pallas
//! device physics (`python/compile/kernels/ref.py`).
//!
//! The digital fast path (millions of column ops) uses this model directly;
//! the AOT artifacts executed over PJRT provide the analog ground truth,
//! and `rust/tests/hlo_cross_validation.rs` pins the two together.

pub mod fefet;
pub mod fet;
pub mod lut;
pub mod miller;

pub use fefet::{
    cell_current, isl_levels, rbl_step, rbl_transient, senseline_current, vt_of_pol,
    write_bit, RblTransient,
};
pub use lut::CellLut;
