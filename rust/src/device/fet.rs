//! 45 nm access-FET I-V model: alpha-power law with smooth subthreshold
//! blending.  Mirrors `python/compile/kernels/ref.py::fet_current`; the
//! cross-validation test pins this against the AOT artifacts.

use crate::config::DeviceParams;

/// Numerically-stable softplus log(1 + e^x).
#[inline]
pub fn softplus(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

/// Smooth effective overdrive: ~(v_gs - v_t) above threshold, exponential
/// decay below, blended by the subthreshold slope n_ss * phi_t.
#[inline]
pub fn overdrive(p: &DeviceParams, v_gs: f64, v_t: f64) -> f64 {
    let u = p.n_ss * p.phi_t;
    u * softplus((v_gs - v_t) / u)
}

/// Drain current (A): I_D = K * Vov^alpha * tanh(V_DS / V_dsat).
#[inline]
pub fn drain_current(p: &DeviceParams, v_gs: f64, v_ds: f64, v_t: f64) -> f64 {
    let vov = overdrive(p, v_gs, v_t);
    let sat = (v_ds.max(0.0) / p.v_dsat).tanh();
    p.k_fet * vov.powf(p.alpha_sat) * sat
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn softplus_limits() {
        assert!((softplus(0.0) - std::f64::consts::LN_2).abs() < 1e-12);
        assert!((softplus(50.0) - 50.0).abs() < 1e-9);
        assert!(softplus(-50.0) < 1e-20);
        assert!(softplus(-50.0) > 0.0);
    }

    #[test]
    fn overdrive_above_threshold_is_linear() {
        let p = p();
        let vov = overdrive(&p, 1.0, 0.3);
        assert!((vov - 0.7).abs() < 1e-6, "vov={vov}");
    }

    #[test]
    fn current_monotone_in_vgs() {
        let p = p();
        let mut last = -1.0;
        for i in 0..100 {
            let vg = i as f64 * 0.02;
            let i_d = drain_current(&p, vg, 1.0, 0.45);
            assert!(i_d > last, "non-monotone at vg={vg}");
            last = i_d;
        }
    }

    #[test]
    fn current_monotone_in_vds_and_saturates() {
        let p = p();
        let lo = drain_current(&p, 1.0, 0.1, 0.45);
        let mid = drain_current(&p, 1.0, 0.5, 0.45);
        let hi = drain_current(&p, 1.0, 1.0, 0.45);
        assert!(lo < mid && mid < hi);
        // tanh saturation: doubling V_DS deep in saturation changes little
        let deep = drain_current(&p, 1.0, 2.0, 0.45);
        assert!((deep - hi) / hi < 0.1);
    }

    #[test]
    fn negative_vds_clamps_to_zero_current() {
        let p = p();
        assert_eq!(drain_current(&p, 1.0, -0.5, 0.45), 0.0);
    }

    #[test]
    fn subthreshold_current_is_tiny_but_positive() {
        let p = p();
        let i = drain_current(&p, 0.2, 1.0, 0.9);
        assert!(i > 0.0);
        assert!(i < 1e-8);
    }
}
