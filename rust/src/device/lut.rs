//! Fast separable device-model evaluation for the engine hot path.
//!
//! The alpha-power FeFET current factorizes exactly:
//!
//! ```text
//! I_D(vg, v_ds, pol, dvt) = K * Vov(u)^alpha * tanh(v_ds / v_dsat)
//!                         = f(u)             * s(v_ds)
//! u = vg - V_T(pol, dvt)  (a single scalar per cell per activation)
//! ```
//!
//! so one 1-D table over `u` (the gate overdrive) and one over `v_ds`
//! replace `exp/ln/powf/tanh` with two linear interpolations.  During an
//! RBL discharge transient `u` is *constant*, so the entire 128-step
//! integration needs ONE `f(u)` evaluation per cell and one `s(v)` lookup
//! per step — this is the §Perf L3 optimization (see EXPERIMENTS.md).
//!
//! Accuracy: 16384-point tables over u in [-2, 2] and v in [0, 1.25*v_read]
//! keep the interpolation error orders of magnitude below the 5e-4
//! cross-validation budget; `tests` pin worst-case error < 1e-5 relative.

use super::fet;
use crate::config::DeviceParams;

const N_U: usize = 16384;
const N_V: usize = 4096;

/// Precomputed separable device tables for one bias family.
#[derive(Clone, Debug)]
pub struct CellLut {
    u_lo: f64,
    u_step_inv: f64,
    /// f(u) = K * Vov(u)^alpha (saturation factor excluded).
    f_of_u: Vec<f64>,
    v_lo: f64,
    v_step_inv: f64,
    /// s(v) = tanh(max(v,0) / v_dsat).
    s_of_v: Vec<f64>,
    /// cached threshold pieces: V_T = vt0 - vt_slope * pol + dvt
    vt0: f64,
    vt_slope: f64,
}

impl CellLut {
    pub fn new(p: &DeviceParams) -> Self {
        let (u_lo, u_hi) = (-2.0, 2.0);
        let u_step = (u_hi - u_lo) / (N_U - 1) as f64;
        let f_of_u = (0..N_U)
            .map(|i| {
                let u = u_lo + i as f64 * u_step;
                let vov = fet::overdrive(p, u, 0.0);
                p.k_fet * vov.powf(p.alpha_sat)
            })
            .collect();
        let (v_lo, v_hi) = (0.0, 1.25 * p.v_read.max(p.vdd));
        let v_step = (v_hi - v_lo) / (N_V - 1) as f64;
        let s_of_v = (0..N_V)
            .map(|i| ((v_lo + i as f64 * v_step) / p.v_dsat).tanh())
            .collect();
        Self {
            u_lo,
            u_step_inv: 1.0 / u_step,
            f_of_u,
            v_lo,
            v_step_inv: 1.0 / v_step,
            s_of_v,
            vt0: p.vt0,
            vt_slope: 0.5 * p.dvt_mw / p.ps,
        }
    }

    #[inline]
    fn interp(table: &[f64], lo: f64, step_inv: f64, x: f64) -> f64 {
        let t = (x - lo) * step_inv;
        let t = t.clamp(0.0, (table.len() - 1) as f64);
        let i = t as usize;
        if i + 1 >= table.len() {
            return table[table.len() - 1];
        }
        let frac = t - i as f64;
        table[i] + (table[i + 1] - table[i]) * frac
    }

    /// Gate overdrive scalar for a cell.
    #[inline]
    pub fn u_of(&self, v_g: f64, pol: f64, dvt: f64) -> f64 {
        v_g - (self.vt0 - self.vt_slope * pol + dvt)
    }

    /// f(u): current with the drain-saturation factor divided out.
    #[inline]
    pub fn f(&self, u: f64) -> f64 {
        Self::interp(&self.f_of_u, self.u_lo, self.u_step_inv, u)
    }

    /// s(v_ds): the drain-saturation factor.
    #[inline]
    pub fn s(&self, v_ds: f64) -> f64 {
        if v_ds <= 0.0 {
            return 0.0;
        }
        Self::interp(&self.s_of_v, self.v_lo, self.v_step_inv, v_ds)
    }

    /// Full cell current (matches `device::cell_current` to < 1e-5 rel).
    #[inline]
    pub fn cell_current(&self, v_g: f64, v_ds: f64, pol: f64, dvt: f64) -> f64 {
        self.f(self.u_of(v_g, pol, dvt)) * self.s(v_ds)
    }

    /// Dual-row senseline current at DC.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn senseline_current(
        &self,
        pol_a: f64,
        pol_b: f64,
        vg1: f64,
        vg2: f64,
        v_ds: f64,
        dvt_a: f64,
        dvt_b: f64,
    ) -> f64 {
        let fa = self.f(self.u_of(vg1, pol_a, dvt_a));
        let fb = self.f(self.u_of(vg2, pol_b, dvt_b));
        (fa + fb) * self.s(v_ds)
    }

    /// Full RBL discharge transient with the separable fast path: the two
    /// `f(u)` factors are hoisted out of the 128-step loop entirely.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn rbl_transient(
        &self,
        p: &DeviceParams,
        pol_a: f64,
        pol_b: f64,
        vg1: f64,
        vg2: f64,
        v0: f64,
        c_rbl: f64,
        dvt_a: f64,
        dvt_b: f64,
    ) -> super::fefet::RblTransient {
        let fsum = self.f(self.u_of(vg1, pol_a, dvt_a)) + self.f(self.u_of(vg2, pol_b, dvt_b));
        let dt = p.t_step;
        let dt_over_c = dt / c_rbl;
        let mut v = v0;
        let mut q = 0.0;
        let mut e = 0.0;
        for _ in 0..p.n_steps {
            let i_sl = fsum * self.s(v);
            q += i_sl * dt;
            e += i_sl * v * dt;
            v = (v - i_sl * dt_over_c).max(0.0);
        }
        super::fefet::RblTransient { v_final: v, q_drawn: q, e_diss: e }
    }
}

/// O(1) RBL-transient evaluation for a fixed (v0, C_RBL) operating point.
///
/// Under the separable current I = f_sum * s(v), the explicit-Euler
/// discharge map `v_final = F(f_sum)` is a smooth scalar function of the
/// summed drive factor alone.  `TransientTable` tabulates F by running
/// the *actual Euler integration* at each grid point (so the semantics
/// are exactly the reference stepping, not the continuous ODE) and
/// interpolates between grid points.  One dual-row voltage-sensing
/// evaluation drops from 128 steps to two `f(u)` lookups + one interp —
/// the second §Perf L3 optimization (see EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct TransientTable {
    f_lo: f64,
    f_step_inv: f64,
    v_final: Vec<f64>,
    pub v0: f64,
    pub c_rbl: f64,
}

const N_F: usize = 4096;

impl TransientTable {
    pub fn new(p: &DeviceParams, lut: &CellLut, v0: f64, c_rbl: f64) -> Self {
        // f_sum range: 0 .. 2 cells at the maximum tabulated overdrive
        let f_hi = 2.0 * lut.f(2.0);
        let f_step = f_hi / (N_F - 1) as f64;
        let dt_over_c = p.t_step / c_rbl;
        let v_final = (0..N_F)
            .map(|i| {
                let f_sum = i as f64 * f_step;
                let mut v = v0;
                for _ in 0..p.n_steps {
                    v = (v - f_sum * lut.s(v) * dt_over_c).max(0.0);
                }
                v
            })
            .collect();
        Self { f_lo: 0.0, f_step_inv: 1.0 / f_step, v_final, v0, c_rbl }
    }

    /// Euler-semantics final RBL voltage for a summed drive factor.
    #[inline]
    pub fn v_final(&self, f_sum: f64) -> f64 {
        CellLut::interp(&self.v_final, self.f_lo, self.f_step_inv, f_sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device;
    use crate::util::rng::Rng;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn lut_matches_exact_cell_current() {
        let p = p();
        let lut = CellLut::new(&p);
        let mut rng = Rng::new(1);
        let mut worst = 0.0f64;
        for _ in 0..20_000 {
            let vg = rng.uniform(0.0, 1.2);
            let vds = rng.uniform(0.0, 1.0);
            let pol = rng.uniform(-p.ps, p.ps);
            let dvt = rng.uniform(-0.08, 0.08);
            let exact = device::cell_current(&p, vg, vds, pol, dvt);
            let fast = lut.cell_current(vg, vds, pol, dvt);
            if exact > 1e-12 {
                worst = worst.max(((fast - exact) / exact).abs());
            } else {
                worst = worst.max((fast - exact).abs() * 1e6);
            }
        }
        assert!(worst < 1e-5, "worst rel err {worst:.2e}");
    }

    #[test]
    fn lut_matches_exact_senseline() {
        let p = p();
        let lut = CellLut::new(&p);
        let mut rng = Rng::new(2);
        for _ in 0..5_000 {
            let pol_a = rng.uniform(-p.ps, p.ps);
            let pol_b = rng.uniform(-p.ps, p.ps);
            let exact = device::senseline_current(
                &p, pol_a, pol_b, p.v_gread1, p.v_gread2, p.v_read, 0.0, 0.0,
            );
            let fast =
                lut.senseline_current(pol_a, pol_b, p.v_gread1, p.v_gread2, p.v_read, 0.0, 0.0);
            assert!(((fast - exact) / exact).abs() < 1e-5);
        }
    }

    #[test]
    fn lut_transient_matches_exact_transient() {
        let p = p();
        let lut = CellLut::new(&p);
        let c = 1024.0 * p.c_rbl_cell;
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            let exact = device::rbl_transient(
                &p,
                p.pol_of_bit(a),
                p.pol_of_bit(b),
                p.v_gread1,
                p.v_gread2,
                p.v_read,
                c,
                0.0,
                0.0,
            );
            let fast = lut.rbl_transient(
                &p,
                p.pol_of_bit(a),
                p.pol_of_bit(b),
                p.v_gread1,
                p.v_gread2,
                p.v_read,
                c,
                0.0,
                0.0,
            );
            assert!(
                (fast.v_final - exact.v_final).abs() < 1e-4,
                "({a},{b}): {} vs {}",
                fast.v_final,
                exact.v_final
            );
            assert!(((fast.q_drawn - exact.q_drawn) / exact.q_drawn).abs() < 1e-4);
            assert!(((fast.e_diss - exact.e_diss) / exact.e_diss).abs() < 1e-4);
        }
    }

    #[test]
    fn transient_table_matches_stepped_lut_transient() {
        let p = p();
        let lut = CellLut::new(&p);
        let c = 1024.0 * p.c_rbl_cell;
        let table = TransientTable::new(&p, &lut, p.v_read, c);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let pol_a = rng.uniform(-p.ps, p.ps);
            let pol_b = rng.uniform(-p.ps, p.ps);
            let dvt_a = rng.uniform(-0.05, 0.05);
            let dvt_b = rng.uniform(-0.05, 0.05);
            let stepped = lut
                .rbl_transient(&p, pol_a, pol_b, p.v_gread1, p.v_gread2, p.v_read, c,
                               dvt_a, dvt_b)
                .v_final;
            let f_sum = lut.f(lut.u_of(p.v_gread1, pol_a, dvt_a))
                + lut.f(lut.u_of(p.v_gread2, pol_b, dvt_b));
            let fast = table.v_final(f_sum);
            assert!(
                (fast - stepped).abs() < 5e-5,
                "table {fast} vs stepped {stepped}"
            );
        }
    }

    #[test]
    fn transient_table_matches_exact_euler_on_canonical_states() {
        let p = p();
        let lut = CellLut::new(&p);
        let c = 1024.0 * p.c_rbl_cell;
        let table = TransientTable::new(&p, &lut, p.v_read, c);
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            let exact = device::rbl_transient(
                &p, p.pol_of_bit(a), p.pol_of_bit(b),
                p.v_gread1, p.v_gread2, p.v_read, c, 0.0, 0.0,
            );
            let f_sum = lut.f(lut.u_of(p.v_gread1, p.pol_of_bit(a), 0.0))
                + lut.f(lut.u_of(p.v_gread2, p.pol_of_bit(b), 0.0));
            let fast = table.v_final(f_sum);
            assert!(
                (fast - exact.v_final).abs() < 2e-4,
                "({a},{b}): {fast} vs {}",
                exact.v_final
            );
        }
    }

    #[test]
    fn out_of_range_inputs_clamp() {
        let p = p();
        let lut = CellLut::new(&p);
        assert_eq!(lut.s(-0.5), 0.0);
        assert!(lut.cell_current(10.0, 1.0, p.ps, 0.0).is_finite());
        assert!(lut.cell_current(-10.0, 1.0, -p.ps, 0.0) >= 0.0);
    }

    #[test]
    fn sensing_decisions_identical_to_exact_path() {
        // the margins are huge relative to LUT error, but pin it anyway:
        // decode every vector via LUT currents + exact references
        let p = p();
        let lut = CellLut::new(&p);
        let refs = crate::sensing::CurrentRefs::derive(&p, p.v_gread1, p.v_gread2);
        let bank = crate::sensing::CurrentSenseBank::new(refs);
        for a in [false, true] {
            for b in [false, true] {
                let i = lut.senseline_current(
                    p.pol_of_bit(a),
                    p.pol_of_bit(b),
                    p.v_gread1,
                    p.v_gread2,
                    p.v_read,
                    0.0,
                    0.0,
                );
                let out = bank.sense(i);
                assert_eq!((out.a(), out.b), (a, b));
            }
        }
    }
}
