//! 1T-FeFET bitcell: polarization -> threshold map + read current, and the
//! dual-row senseline composition that ADRA's one-to-one mapping rests on.
//! Mirrors `python/compile/kernels/ref.py`.

use super::{fet, miller};
use crate::config::DeviceParams;

/// Threshold voltage for stored polarization `pol` (plus a per-cell
/// variation offset `dvt`): +P (LRS, '1') lowers V_T, -P raises it.
#[inline]
pub fn vt_of_pol(p: &DeviceParams, pol: f64, dvt: f64) -> f64 {
    p.vt0 - 0.5 * p.dvt_mw * (pol / p.ps) + dvt
}

/// Bitcell read current (A) at wordline voltage `v_g`, drain bias `v_ds`.
#[inline]
pub fn cell_current(p: &DeviceParams, v_g: f64, v_ds: f64, pol: f64, dvt: f64) -> f64 {
    fet::drain_current(p, v_g, v_ds, vt_of_pol(p, pol, dvt))
}

/// ADRA senseline current: word A on the V_GREAD1 row, word B on the
/// V_GREAD2 row, summed on the shared senseline (Fig. 3(a)).
#[inline]
pub fn senseline_current(
    p: &DeviceParams,
    pol_a: f64,
    pol_b: f64,
    vg1: f64,
    vg2: f64,
    v_ds: f64,
    dvt_a: f64,
    dvt_b: f64,
) -> f64 {
    cell_current(p, vg1, v_ds, pol_a, dvt_a) + cell_current(p, vg2, v_ds, pol_b, dvt_b)
}

/// The four I_SL levels for bit vectors (A,B) in {00,01,10,11} at the DC
/// operating point — the Fig. 3(c) table.  Index = (A<<1)|B.
pub fn isl_levels(p: &DeviceParams, vg1: f64, vg2: f64) -> [f64; 4] {
    let mut out = [0.0; 4];
    for a in 0..2usize {
        for b in 0..2usize {
            out[(a << 1) | b] = senseline_current(
                p,
                p.pol_of_bit(a == 1),
                p.pol_of_bit(b == 1),
                vg1,
                vg2,
                p.v_read,
                0.0,
                0.0,
            );
        }
    }
    out
}

/// One explicit-Euler RBL discharge step (voltage-based sensing):
/// returns `(v_next, i_sl)`.  Mirrors `ref.rbl_step`.
#[inline]
pub fn rbl_step(
    p: &DeviceParams,
    v_rbl: f64,
    pol_a: f64,
    pol_b: f64,
    vg1: f64,
    vg2: f64,
    c_rbl: f64,
    dt: f64,
    dvt_a: f64,
    dvt_b: f64,
) -> (f64, f64) {
    let i_sl = senseline_current(p, pol_a, pol_b, vg1, vg2, v_rbl, dvt_a, dvt_b);
    let v_next = (v_rbl - i_sl * dt / c_rbl).max(0.0);
    (v_next, i_sl)
}

/// Full RBL discharge transient over `p.n_steps` steps.  Returns the final
/// voltage, total charge drawn, and dissipated energy — the behavioral
/// mirror of the `transient_cim` artifact for one column.
pub fn rbl_transient(
    p: &DeviceParams,
    pol_a: f64,
    pol_b: f64,
    vg1: f64,
    vg2: f64,
    v0: f64,
    c_rbl: f64,
    dvt_a: f64,
    dvt_b: f64,
) -> RblTransient {
    let mut v = v0;
    let mut q = 0.0;
    let mut e = 0.0;
    for _ in 0..p.n_steps {
        let (v_next, i_sl) = rbl_step(p, v, pol_a, pol_b, vg1, vg2, c_rbl, dt_of(p), dvt_a, dvt_b);
        q += i_sl * dt_of(p);
        e += i_sl * v * dt_of(p);
        v = v_next;
    }
    RblTransient { v_final: v, q_drawn: q, e_diss: e }
}

#[inline]
fn dt_of(p: &DeviceParams) -> f64 {
    p.t_step
}

/// Result of a voltage-sensing discharge transient for one column.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RblTransient {
    pub v_final: f64,
    pub q_drawn: f64,
    pub e_diss: f64,
}

/// Behavioral write: relax polarization under a SET/RESET pulse long
/// enough to reach the stored state (used by the fast digital path; the
/// `write_transient` artifact models the full waveform).
pub fn write_bit(p: &DeviceParams, bit: bool) -> f64 {
    let v = if bit { p.v_set } else { p.v_reset };
    let settled = miller::relax(p, p.pol_of_bit(!bit), v, p.tau_fe, 64);
    // the pulse must actually have switched the polarization sign...
    debug_assert!(
        settled.signum() == p.pol_of_bit(bit).signum(),
        "write pulse failed to switch: settled {settled}"
    );
    // ...then the cell relaxes to the canonical remanent stored state, so
    // digital reads are exact and the planes ABI matches the artifacts
    p.pol_of_bit(bit)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn vt_mapping_window() {
        let p = p();
        let vt_lrs = vt_of_pol(&p, p.pol_of_bit(true), 0.0);
        let vt_hrs = vt_of_pol(&p, p.pol_of_bit(false), 0.0);
        assert!(vt_lrs < vt_hrs);
        let window = vt_hrs - vt_lrs;
        assert!((window - p.dvt_mw * p.p_store).abs() < 1e-12);
    }

    #[test]
    fn adra_levels_distinct_and_ordered() {
        let p = p();
        let l = isl_levels(&p, p.v_gread1, p.v_gread2);
        // I00 < I10 < I01 < I11 (B on the stronger wordline)
        assert!(l[0b00] < l[0b10]);
        assert!(l[0b10] < l[0b01]);
        assert!(l[0b01] < l[0b11]);
    }

    #[test]
    fn adra_margins_exceed_1ua() {
        let p = p();
        let mut l = isl_levels(&p, p.v_gread1, p.v_gread2).to_vec();
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in l.windows(2) {
            assert!(w[1] - w[0] > 1e-6, "margin {} A", w[1] - w[0]);
        }
    }

    #[test]
    fn symmetric_biasing_is_many_to_one() {
        let p = p();
        let l = isl_levels(&p, p.v_gread2, p.v_gread2);
        assert!((l[0b01] - l[0b10]).abs() / l[0b01] < 1e-9);
        assert!(l[0b00] < l[0b01] && l[0b01] < l[0b11]);
    }

    #[test]
    fn rbl_discharge_monotone_and_ordered() {
        let p = p();
        let c = 1024.0 * p.c_rbl_cell;
        let mut finals = Vec::new();
        for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
            let t = rbl_transient(
                &p,
                p.pol_of_bit(a),
                p.pol_of_bit(b),
                p.v_gread1,
                p.v_gread2,
                p.v_read,
                c,
                0.0,
                0.0,
            );
            assert!(t.v_final <= p.v_read);
            assert!(t.q_drawn >= 0.0 && t.e_diss >= 0.0);
            finals.push(t.v_final);
        }
        // deeper discharge for larger I_SL: v00 > v10 > v01 > v11
        assert!(finals[0] > finals[1] && finals[1] > finals[2] && finals[2] > finals[3]);
    }

    #[test]
    fn rbl_voltage_margins_exceed_50mv() {
        let p = p();
        let c = 1024.0 * p.c_rbl_cell;
        let mut finals: Vec<f64> = [(false, false), (true, false), (false, true), (true, true)]
            .iter()
            .map(|&(a, b)| {
                rbl_transient(
                    &p,
                    p.pol_of_bit(a),
                    p.pol_of_bit(b),
                    p.v_gread1,
                    p.v_gread2,
                    p.v_read,
                    c,
                    0.0,
                    0.0,
                )
                .v_final
            })
            .collect();
        finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in finals.windows(2) {
            assert!(w[1] - w[0] > 0.050, "voltage margin {} V", w[1] - w[0]);
        }
    }

    #[test]
    fn charge_conservation() {
        let p = p();
        let c = 1024.0 * p.c_rbl_cell;
        let t = rbl_transient(
            &p,
            p.pol_of_bit(true),
            p.pol_of_bit(true),
            p.v_gread1,
            p.v_gread2,
            p.v_read,
            c,
            0.0,
            0.0,
        );
        let dv = p.v_read - t.v_final;
        assert!((t.q_drawn - c * dv).abs() / t.q_drawn < 1e-3);
    }

    #[test]
    fn write_bit_reaches_stored_states() {
        let p = p();
        assert!(write_bit(&p, true) >= p.pol_of_bit(true));
        assert!(write_bit(&p, false) <= p.pol_of_bit(false));
    }

    #[test]
    fn variation_shifts_current() {
        let p = p();
        let base = cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(true), 0.0);
        let slow = cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(true), 0.05);
        let fast = cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(true), -0.05);
        assert!(slow < base && base < fast);
    }
}
