//! The calibrated energy/latency model.
//!
//! All public costs are **per word** (word_bits columns).  The RBL and WL
//! terms are physical (C V^2 with the configured per-cell capacitances);
//! the flow / periphery / near-memory terms carry the calibration
//! constants documented in `constants.rs`.

use super::breakdown::{EnergyBreakdown, OpCost};
use super::constants as k;
use crate::config::{SensingScheme, SimConfig};

/// Energy/latency model bound to one array configuration.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    cfg: SimConfig,
}

impl EnergyModel {
    pub fn new(cfg: &SimConfig) -> Self {
        Self { cfg: cfg.clone() }
    }

    pub fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    #[inline]
    fn nscale(&self) -> f64 {
        self.cfg.rows as f64 / k::REF_ROWS
    }

    /// Per-column RBL capacitance (F).
    #[inline]
    fn c_rbl(&self) -> f64 {
        self.cfg.c_rbl()
    }

    /// Wordline charge energy per column share for `n_wl` asserted rows.
    #[inline]
    fn e_wl_col(&self, n_wl: f64, vg: f64) -> f64 {
        n_wl * self.cfg.device.c_wl_cell * vg * vg
    }

    fn w(&self) -> f64 {
        self.cfg.word_bits as f64
    }

    // ---- latency primitives ------------------------------------------------

    /// Standard read latency at this array size.
    pub fn t_read(&self) -> f64 {
        k::T_FIX + k::T_VAR_1024 * self.nscale()
    }

    /// Near-memory transfer+compute latency (baseline path).
    pub fn t_near(&self) -> f64 {
        k::T_NEAR_1024 * self.nscale()
    }

    /// ADRA CiM latency for the configured scheme.
    pub fn t_cim(&self) -> f64 {
        let ns = self.nscale();
        match self.cfg.scheme {
            SensingScheme::Current => self.t_read() + k::T_CIM_EXTRA_CUR,
            SensingScheme::VoltagePrecharged => {
                k::T_FIX + k::T_CIM_EXTRA_V1 + k::K_DISCHARGE_V1 * k::T_VAR_1024 * ns
            }
            SensingScheme::VoltageDischarged => {
                self.t_read() + k::T_CIM_EXTRA_V2_FIX + k::T_CIM_EXTRA_V2_VAR_1024 * ns
            }
        }
    }

    // ---- per-word energy costs ---------------------------------------------

    /// One standard memory read (single row, word_bits columns).
    pub fn read_cost(&self) -> OpCost {
        let d = &self.cfg.device;
        let ns = self.nscale();
        let w = self.w();
        let (rbl_col, flow_col, periph_col) = match self.cfg.scheme {
            SensingScheme::Current => (
                self.c_rbl() * d.v_read * d.v_read,
                k::FLOW_READ_1024 * ns,
                k::E_SA_CUR + k::E_DECODE,
            ),
            SensingScheme::VoltagePrecharged => (
                self.c_rbl() * d.vdd * k::SWING_READ_V1,
                0.0, // discharge-limited; flow folded into the swing
                k::F_READ_V1,
            ),
            SensingScheme::VoltageDischarged => (
                self.c_rbl() * d.vdd * d.vdd,
                0.0,
                k::F_READ_V2,
            ),
        };
        OpCost {
            energy: EnergyBreakdown {
                rbl: rbl_col * w,
                wl: self.e_wl_col(1.0, d.v_gread2) * w,
                flow: flow_col * w,
                peripheral: periph_col * w,
                leakage: 0.0,
            },
            latency: self.t_read(),
        }
    }

    /// One ADRA CiM access (asymmetric dual-row activation + 3 SAs +
    /// compute module), per word.  This covers read2 / any Boolean fn /
    /// one add-or-subtract stage — they share the access; only the
    /// near-zero compute-module select differs.
    pub fn cim_cost(&self) -> OpCost {
        let d = &self.cfg.device;
        let ns = self.nscale();
        let w = self.w();
        let (rbl_col, flow_col, periph_col) = match self.cfg.scheme {
            SensingScheme::Current => (
                self.c_rbl() * d.v_read * d.v_read,
                k::FLOW_CIM_1024 * ns,
                3.0 * k::E_SA_CUR + k::E_CM_CUR + k::E_DECODE,
            ),
            SensingScheme::VoltagePrecharged => (
                self.c_rbl() * d.vdd * k::SWING_CIM_V1,
                0.0,
                k::F_CIM_V1,
            ),
            SensingScheme::VoltageDischarged => (
                self.c_rbl() * d.vdd * d.vdd,
                0.0,
                k::F_CIM_V2,
            ),
        };
        let wl = (self.e_wl_col(1.0, d.v_gread1) + self.e_wl_col(1.0, d.v_gread2)) * w;
        OpCost {
            energy: EnergyBreakdown {
                rbl: rbl_col * w,
                wl,
                flow: flow_col * w,
                peripheral: periph_col * w,
                leakage: 0.0,
            },
            latency: self.t_cim(),
        }
    }

    /// Baseline non-commutative op (paper's comparison point): two full
    /// reads + near-memory compute, per word.
    pub fn baseline_cost(&self) -> OpCost {
        let ns = self.nscale();
        let w = self.w();
        let near_col = match self.cfg.scheme {
            SensingScheme::Current => k::E_NEAR_CUR_1024,
            SensingScheme::VoltagePrecharged => k::E_NEAR_V1_1024,
            SensingScheme::VoltageDischarged => k::E_NEAR_V2_1024,
        } * ns;
        let read = self.read_cost();
        let two_reads = OpCost {
            energy: read.energy.scale(2.0),
            latency: 2.0 * read.latency,
        };
        let near = OpCost {
            energy: EnergyBreakdown {
                peripheral: near_col * w,
                ..EnergyBreakdown::default()
            },
            latency: self.t_near(),
        };
        two_reads.then(&near)
    }

    /// One behavioral write (word).
    pub fn write_cost(&self) -> OpCost {
        let d = &self.cfg.device;
        let w = self.w();
        // write drives the WL to V_SET / |V_RESET| and the write path
        OpCost {
            energy: EnergyBreakdown {
                rbl: self.c_rbl() * d.vdd * d.vdd * w,
                wl: self.e_wl_col(1.0, d.v_set.abs().max(d.v_reset.abs())) * w,
                flow: 0.0,
                peripheral: (k::E_DECODE + 2.0e-15) * w,
                leakage: 0.0,
            },
            latency: k::T_WRITE,
        }
    }

    // ---- Fig. 5 analyses ---------------------------------------------------

    /// Standby leakage power (W) of one precharged column (scheme 1 only).
    pub fn leak_power_col(&self) -> f64 {
        self.cfg.rows as f64 * k::I_LEAK_CELL * self.cfg.device.vdd
    }

    /// Per-op energy at a given CiM issue frequency, charging scheme-1 ops
    /// with the standby leakage of the whole row's RBLs between ops
    /// (Fig. 5(a)).  `scheme` selects which policy to evaluate.
    pub fn cim_energy_at_frequency(&self, scheme: SensingScheme, freq: f64) -> f64 {
        let mut m = self.clone();
        m.cfg.scheme = scheme;
        let e_op = m.cim_cost().energy.total();
        match scheme {
            SensingScheme::VoltagePrecharged => {
                e_op + self.w() * self.leak_power_col() / freq
            }
            _ => e_op,
        }
    }

    /// Half-selected (pseudo-CiM) recharge energy per column, scheme 1.
    pub fn e_halfselect_col(&self) -> f64 {
        self.c_rbl() * self.cfg.device.vdd * k::V_PSEUDO_AVG
    }

    /// Total energy of one row activation computing on a fraction
    /// `parallelism` of the row's words (Fig. 5(b)).
    pub fn row_activation_energy(&self, scheme: SensingScheme, parallelism: f64) -> f64 {
        let mut m = self.clone();
        m.cfg.scheme = scheme;
        let words = self.cfg.words_per_row() as f64;
        let n_cim = (words * parallelism).max(1.0);
        let e_cim_word = m.cim_cost().energy.total();
        match scheme {
            SensingScheme::VoltagePrecharged => {
                // every word shares the asserted WLs; unselected words
                // pseudo-discharge and must be recharged
                let n_half = words - n_cim;
                n_cim * e_cim_word + n_half * self.w() * self.e_halfselect_col()
            }
            _ => n_cim * e_cim_word,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::energy::breakdown::Improvement;

    fn model(n: usize, s: SensingScheme) -> EnergyModel {
        EnergyModel::new(&SimConfig::square(n, s))
    }

    // ---- Fig. 4: current sensing -------------------------------------------

    #[test]
    fn fig4_read_rbl_share_91pct() {
        let m = model(1024, SensingScheme::Current);
        let frac = m.read_cost().energy.rbl_fraction();
        assert!((frac - 0.91).abs() < 0.01, "RBL share {frac}");
    }

    #[test]
    fn fig4_cim_is_1_24x_read() {
        let m = model(1024, SensingScheme::Current);
        let ratio = m.cim_cost().energy.total() / m.read_cost().energy.total();
        assert!((ratio - 1.24).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn fig4_cim_rbl_share_74pct() {
        let m = model(1024, SensingScheme::Current);
        let frac = m.cim_cost().energy.rbl_fraction();
        assert!((frac - 0.74).abs() < 0.02, "CiM RBL share {frac}");
    }

    #[test]
    fn fig4_headline_at_1024() {
        let m = model(1024, SensingScheme::Current);
        let imp = Improvement::of(&m.cim_cost(), &m.baseline_cost());
        assert!((imp.energy_decrease - 0.4118).abs() < 0.005, "{imp:?}");
        assert!((imp.speedup - 1.94).abs() < 0.02, "{imp:?}");
        assert!((imp.edp_decrease - 0.6904).abs() < 0.015, "{imp:?}");
    }

    #[test]
    fn fig4_benefits_increase_with_array_size() {
        let mut last_e = 0.0;
        let mut last_s = 0.0;
        for n in [256usize, 512, 1024] {
            let m = model(n, SensingScheme::Current);
            let imp = Improvement::of(&m.cim_cost(), &m.baseline_cost());
            assert!(imp.energy_decrease > last_e, "n={n}");
            assert!(imp.speedup > last_s, "n={n}");
            last_e = imp.energy_decrease;
            last_s = imp.speedup;
        }
    }

    // ---- Fig. 6: voltage scheme 1 ------------------------------------------

    #[test]
    fn fig6_cim_rbl_is_3x_read_rbl() {
        let m = model(1024, SensingScheme::VoltagePrecharged);
        let ratio = m.cim_cost().energy.rbl / m.read_cost().energy.rbl;
        assert!((ratio - 3.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn fig6_energy_overhead_20_to_23pct() {
        for (n, lo, hi) in [(256usize, 0.18, 0.22), (1024, 0.21, 0.25)] {
            let m = model(n, SensingScheme::VoltagePrecharged);
            let imp = Improvement::of(&m.cim_cost(), &m.baseline_cost());
            let overhead = -imp.energy_decrease;
            assert!(overhead > lo && overhead < hi, "n={n} overhead {overhead}");
        }
    }

    #[test]
    fn fig6_speedup_and_edp_band() {
        let m256 = model(256, SensingScheme::VoltagePrecharged);
        let m1024 = model(1024, SensingScheme::VoltagePrecharged);
        let i256 = Improvement::of(&m256.cim_cost(), &m256.baseline_cost());
        let i1024 = Improvement::of(&m1024.cim_cost(), &m1024.baseline_cost());
        assert!((i256.speedup - 1.57).abs() < 0.03, "{i256:?}");
        assert!((i1024.speedup - 1.73).abs() < 0.03, "{i1024:?}");
        assert!((i256.edp_decrease - 0.2326).abs() < 0.02, "{i256:?}");
        assert!((i1024.edp_decrease - 0.2881).abs() < 0.02, "{i1024:?}");
    }

    // ---- Fig. 7: voltage scheme 2 ------------------------------------------

    #[test]
    fn fig7_bands() {
        let m256 = model(256, SensingScheme::VoltageDischarged);
        let m1024 = model(1024, SensingScheme::VoltageDischarged);
        let i256 = Improvement::of(&m256.cim_cost(), &m256.baseline_cost());
        let i1024 = Improvement::of(&m1024.cim_cost(), &m1024.baseline_cost());
        assert!((i256.energy_decrease - 0.355).abs() < 0.02, "{i256:?}");
        assert!((i1024.energy_decrease - 0.458).abs() < 0.02, "{i1024:?}");
        assert!((i256.speedup - 1.945).abs() < 0.02, "{i256:?}");
        assert!((i1024.speedup - 1.983).abs() < 0.02, "{i1024:?}");
        assert!((i256.edp_decrease - 0.6683).abs() < 0.02, "{i256:?}");
        assert!((i1024.edp_decrease - 0.726).abs() < 0.02, "{i1024:?}");
    }

    #[test]
    fn fig7_rbl_dominates_both_read_and_cim() {
        let m = model(1024, SensingScheme::VoltageDischarged);
        assert!(m.read_cost().energy.rbl_fraction() > 0.8);
        assert!(m.cim_cost().energy.rbl_fraction() > 0.8);
    }

    // ---- Fig. 5 crossovers --------------------------------------------------

    #[test]
    fn fig5a_frequency_crossover_near_7_53mhz() {
        let m = model(1024, SensingScheme::VoltagePrecharged);
        // binary search the crossover frequency
        let (mut lo, mut hi): (f64, f64) = (1e5, 1e9);
        for _ in 0..60 {
            let mid = (lo * hi).sqrt();
            let e1 = m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, mid);
            let e2 = m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, mid);
            if e1 > e2 {
                lo = mid; // scheme 1 still worse -> crossover above
            } else {
                hi = mid;
            }
        }
        let f = (lo * hi).sqrt();
        assert!((f - 7.53e6).abs() / 7.53e6 < 0.05, "crossover {f}");
    }

    #[test]
    fn fig5b_parallelism_crossover_near_42pct() {
        let m = model(1024, SensingScheme::VoltagePrecharged);
        let (mut lo, mut hi) = (1.0 / 32.0, 1.0);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            let e1 = m.row_activation_energy(SensingScheme::VoltagePrecharged, mid);
            let e2 = m.row_activation_energy(SensingScheme::VoltageDischarged, mid);
            if e1 > e2 {
                lo = mid; // scheme 1 still worse (half-select dominated)
            } else {
                hi = mid;
            }
        }
        let p = 0.5 * (lo + hi);
        assert!((p - 0.42).abs() < 0.04, "crossover P {p}");
    }

    #[test]
    fn leakage_only_charged_to_scheme1() {
        let m = model(1024, SensingScheme::Current);
        let e_hi = m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, 1e6);
        let e_lo = m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, 1e9);
        assert_eq!(e_hi, e_lo);
        let s1_hi = m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, 1e9);
        let s1_lo = m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, 1e6);
        assert!(s1_lo > s1_hi);
    }

    #[test]
    fn write_cost_is_positive_and_slow() {
        let m = model(1024, SensingScheme::Current);
        let w = m.write_cost();
        assert!(w.energy.total() > 0.0);
        assert!(w.latency > m.read_cost().latency);
    }
}
