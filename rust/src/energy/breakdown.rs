//! Energy/latency accounting types: the per-component breakdown the paper
//! plots in Fig. 4(a) / 6(a) / 7(a), and the OpCost (energy x latency)
//! that every engine result carries.

/// Energy components of one array access, in joules (per word unless
/// stated otherwise).  Component names follow Fig. 4(a).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Read-bitline charging / recharging.
    pub rbl: f64,
    /// Wordline charging/discharging.
    pub wl: f64,
    /// Read-current flow + sensing current.
    pub flow: f64,
    /// Peripheral circuitry: sense amplifiers + compute module + decoder.
    pub peripheral: f64,
    /// Standby leakage attributed to this op (scheme 1 precharged RBLs).
    pub leakage: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.rbl + self.wl + self.flow + self.peripheral + self.leakage
    }

    /// RBL share of the total — the "dominant component" statistic.
    pub fn rbl_fraction(&self) -> f64 {
        self.rbl / self.total()
    }

    pub fn add(&self, other: &EnergyBreakdown) -> EnergyBreakdown {
        EnergyBreakdown {
            rbl: self.rbl + other.rbl,
            wl: self.wl + other.wl,
            flow: self.flow + other.flow,
            peripheral: self.peripheral + other.peripheral,
            leakage: self.leakage + other.leakage,
        }
    }

    pub fn scale(&self, k: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            rbl: self.rbl * k,
            wl: self.wl * k,
            flow: self.flow * k,
            peripheral: self.peripheral * k,
            leakage: self.leakage * k,
        }
    }
}

/// Energy + latency of one operation; EDP is the figure of merit the
/// paper's headline claim (23.2%-72.6% decrease) is stated in.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    pub energy: EnergyBreakdown,
    /// Latency in seconds.
    pub latency: f64,
}

impl OpCost {
    pub fn edp(&self) -> f64 {
        self.energy.total() * self.latency
    }

    /// Serial composition: energies add, latencies add.
    pub fn then(&self, next: &OpCost) -> OpCost {
        OpCost {
            energy: self.energy.add(&next.energy),
            latency: self.latency + next.latency,
        }
    }

    /// Serial repetition of this op `n` times — bulk pricing (e.g. the
    /// planner charges a fused group's followers in one step).
    pub fn repeat(&self, n: u64) -> OpCost {
        let k = n as f64;
        OpCost { energy: self.energy.scale(k), latency: self.latency * k }
    }
}

/// Relative improvement metrics of `ours` vs `baseline`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Improvement {
    /// 1 - E_ours / E_base (positive = we use less energy).
    pub energy_decrease: f64,
    /// t_base / t_ours.
    pub speedup: f64,
    /// 1 - EDP_ours / EDP_base.
    pub edp_decrease: f64,
}

impl Improvement {
    pub fn of(ours: &OpCost, baseline: &OpCost) -> Self {
        Self {
            energy_decrease: 1.0 - ours.energy.total() / baseline.energy.total(),
            speedup: baseline.latency / ours.latency,
            edp_decrease: 1.0 - ours.edp() / baseline.edp(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(rbl: f64) -> EnergyBreakdown {
        EnergyBreakdown { rbl, wl: 1.0, flow: 2.0, peripheral: 3.0, leakage: 0.0 }
    }

    #[test]
    fn total_and_fraction() {
        let b = bd(94.0);
        assert_eq!(b.total(), 100.0);
        assert_eq!(b.rbl_fraction(), 0.94);
    }

    #[test]
    fn add_and_scale() {
        let b = bd(4.0).add(&bd(4.0));
        assert_eq!(b.total(), 20.0);
        assert_eq!(b.scale(0.5).total(), 10.0);
    }

    #[test]
    fn edp_and_composition() {
        let a = OpCost { energy: bd(4.0), latency: 2.0 };
        let b = OpCost { energy: bd(14.0), latency: 3.0 };
        assert_eq!(a.edp(), 20.0);
        let c = a.then(&b);
        assert_eq!(c.latency, 5.0);
        assert_eq!(c.energy.total(), 30.0);
    }

    #[test]
    fn repeat_is_n_serial_compositions() {
        let a = OpCost { energy: bd(4.0), latency: 2.0 };
        let r = a.repeat(3);
        assert_eq!(r.latency, 6.0);
        assert_eq!(r.energy.total(), 30.0);
        assert_eq!(a.repeat(0).energy.total(), 0.0);
    }

    #[test]
    fn improvement_identity() {
        let a = OpCost { energy: bd(4.0), latency: 2.0 };
        let imp = Improvement::of(&a, &a);
        assert!(imp.energy_decrease.abs() < 1e-12);
        assert!((imp.speedup - 1.0).abs() < 1e-12);
        assert!(imp.edp_decrease.abs() < 1e-12);
    }

    #[test]
    fn improvement_math() {
        let ours = OpCost { energy: bd(4.0), latency: 1.0 };
        let base = OpCost { energy: bd(14.0), latency: 2.0 };
        let imp = Improvement::of(&ours, &base);
        assert!((imp.energy_decrease - 0.5).abs() < 1e-12);
        assert!((imp.speedup - 2.0).abs() < 1e-12);
        assert!((imp.edp_decrease - 0.75).abs() < 1e-12);
    }
}
