//! Calibrated energy / latency / EDP model (paper Figs. 4-7).
//!
//! See `constants.rs` for the calibration derivation and DESIGN.md §6 for
//! the methodology: physical C·V² terms where the paper gives physics,
//! paper-pinned constants where it gives only relative numbers.

pub mod breakdown;
pub mod constants;
pub mod model;

pub use breakdown::{EnergyBreakdown, Improvement, OpCost};
pub use model::EnergyModel;
