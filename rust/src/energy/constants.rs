//! Calibration constants of the energy/latency model.
//!
//! The paper reports *relative* numbers (percent decompositions, speedups,
//! EDP decreases), not absolute joules, so the model is an analytic
//! capacitance/latency model whose free constants are pinned by the
//! paper's own statements.  Derivation (see DESIGN.md §6 and
//! EXPERIMENTS.md):
//!
//! * current sensing @1024x1024: RBL = 91% of read, CiM = 1.24x read,
//!   energy decrease 41.18%, speedup 1.94x  (Fig. 4);
//! * voltage scheme 1: CiM RBL = 3x read RBL (6 sense-margin units vs 2),
//!   energy overhead 20%(@256) - 23%(@1024), speedup 1.57-1.73x, EDP
//!   decrease 23.26 - 28.81%  (Fig. 6);
//! * voltage scheme 2: energy decrease 35.5-45.8%, speedup 1.945-1.983x,
//!   EDP decrease 66.83-72.6%  (Fig. 7);
//! * scheme1/scheme2 crossovers at 7.53 MHz and P ~= 42%  (Fig. 5).
//!
//! The paper's ranges are internally consistent with
//! `EDP_dec = 1 - E_ratio / speedup` at both ends, which is what makes
//! this calibration well-posed.  All constants are per COLUMN at the
//! reference 1024x1024 geometry; `model.rs` scales them with array size.

/// Reference array size the constants are quoted at.
pub const REF_ROWS: f64 = 1024.0;

// ---------------------------------------------------------------------------
// Latency (seconds).  t_read(n) = T_FIX + T_VAR * n/1024;
// t_near(n) = T_NEAR * n/1024 (near-memory datapath spans the array width).
// ---------------------------------------------------------------------------

/// Fixed read latency: decoder + SA resolution.
pub const T_FIX: f64 = 0.3e-9;
/// Array-size-proportional read latency (WL RC + RBL settle) at 1024 rows.
pub const T_VAR_1024: f64 = 0.7e-9;
/// Near-memory compute/transfer latency at 1024 (baseline only).
pub const T_NEAR_1024: f64 = 0.2e-9;
/// Behavioral write pulse (SET/RESET) duration.
pub const T_WRITE: f64 = 10e-9;

/// Current sensing: extra CiM latency (3-SA resolution + compute module).
pub const T_CIM_EXTRA_CUR: f64 = 0.134e-9;
/// Scheme 1: extra fixed CiM latency.
pub const T_CIM_EXTRA_V1: f64 = 0.1255e-9;
/// Scheme 1: discharge-time stretch on the variable part (6-margin vs
/// 2-margin discharge at roughly 2.4x average current).
pub const K_DISCHARGE_V1: f64 = 1.209;
/// Scheme 2: extra CiM latency, fixed + size-proportional parts.
pub const T_CIM_EXTRA_V2_FIX: f64 = 0.0157e-9;
pub const T_CIM_EXTRA_V2_VAR_1024: f64 = 0.0937e-9;

// ---------------------------------------------------------------------------
// Current-based sensing energies (joules per column).
// ---------------------------------------------------------------------------

/// Read-current flow + sense energy at 1024 rows (standard read).
pub const FLOW_READ_1024: f64 = 17.0e-15;
/// CiM flow energy at 1024 rows: two cells at higher average I_SL over a
/// slightly longer sense window; value closes CiM = 1.24x read.
pub const FLOW_CIM_1024: f64 = 58.85e-15;
/// One current sense amplifier firing.
pub const E_SA_CUR: f64 = 3.0e-15;
/// Row/column decoder share.
pub const E_DECODE: f64 = 0.05e-15;
/// Compute-module dynamic energy (per column, current-sensing sizing).
pub const E_CM_CUR: f64 = 6.0e-15;
/// Near-memory compute + datapath energy at 1024 (baseline subtract);
/// scales with array width (periphery wiring).
pub const E_NEAR_CUR_1024: f64 = 24.33e-15;

// ---------------------------------------------------------------------------
// Voltage-based sensing (schemes 1 & 2).
// ---------------------------------------------------------------------------

/// Scheme 1 read RBL swing: 2 sense-margin units (2 * 50 mV).
pub const SWING_READ_V1: f64 = 0.1;
/// Scheme 1 CiM RBL swing: 6 sense-margin units -> the 3x RBL energy the
/// paper reports.
pub const SWING_CIM_V1: f64 = 0.3;
/// Scheme 1 fixed read periphery (voltage SA + decode).
pub const F_READ_V1: f64 = 1.2e-15;
/// Scheme 1 fixed CiM periphery (3 voltage SAs + compute module + decode).
pub const F_CIM_V1: f64 = 2.37e-15;
/// Scheme 1 near-memory energy at 1024.
pub const E_NEAR_V1_1024: f64 = 8.52e-15;

/// Scheme 2 fixed read periphery (RBL driver + precharge control + SA).
pub const F_READ_V2: f64 = 15.0e-15;
/// Scheme 2 fixed CiM periphery.
pub const F_CIM_V2: f64 = 34.9e-15;
/// Scheme 2 near-memory energy at 1024.
pub const E_NEAR_V2_1024: f64 = 2.6e-15;

// ---------------------------------------------------------------------------
// Fig. 5 crossover calibration.
// ---------------------------------------------------------------------------

/// Effective per-cell standby leakage current (A) on a precharged RBL
/// (junction + GIDL + SA bias).  Calibrated so the scheme1/scheme2
/// energy-per-op crossover falls at the paper's 7.53 MHz (Fig. 5(a)).
pub const I_LEAK_CELL: f64 = 1.285e-9;

/// Average pseudo-CiM discharge (V) of a half-selected column during a
/// scheme-1 CiM window, averaged over stored-data vectors.  Calibrated so
/// the parallelism crossover falls at the paper's P ~= 42% (Fig. 5(b)).
pub const V_PSEUDO_AVG: f64 = 0.62;
