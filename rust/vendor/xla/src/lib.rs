//! Offline stub of the `xla` PJRT bindings.
//!
//! The offline build environment has no XLA/PJRT shared libraries, so this
//! vendored stub keeps the `runtime` module compiling with the exact API
//! surface `runtime::client` uses.  Every entry path fails at runtime with
//! a clear message; the repo's PJRT code paths all gate on
//! `ArtifactManifest::load*` succeeding first, so in practice the stub is
//! never reached unless someone generates artifacts without installing the
//! real bindings — and then the error says exactly that.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT is unavailable in this offline build (the `xla` \
     dependency is a vendored stub); the behavioral AnalogBackend serves all engines";

/// Stub error carrying the unavailability message.
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("{what}: {UNAVAILABLE}"))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (stub: parsing always fails).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Compiled executable handle (stub: execution always fails).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn scalar(_v: f32) -> Self {
        Self::default()
    }

    pub fn vec1(_v: &[f32]) -> Self {
        Self::default()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_path_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::scalar(1.0).to_vec::<f32>().is_err());
    }
}
