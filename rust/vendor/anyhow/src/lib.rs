//! Offline stand-in for the `anyhow` crate.
//!
//! The offline build environment cannot fetch crates.io dependencies, so
//! this vendored stub provides exactly the API subset the repo uses:
//! `anyhow::{Error, Result, Context, anyhow!}`.  Semantics match real
//! anyhow for that subset: contexts wrap outermost-first, `?` converts any
//! `std::error::Error`, and `Error` itself deliberately does NOT implement
//! `std::error::Error` (that is what makes the blanket `From` coherent —
//! the same trick the real crate uses).

use std::fmt;

/// A type-erased error: a rendered message with wrapped context.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("boom {}", 42))
    }

    #[test]
    fn macro_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }
}
